"""Batched, multi-timestep SNN inference engine (fused timestep loop).

This is the path from a DVS event tensor to output spike counts that the
chip actually takes: every timestep, every layer, weight->Vmem accumulation
fused with the neuron update, state carried across timesteps.  The seed repo
modeled one macro drain / one GEMM at a time; the engine closes the loop:

  events (T, B, H, W, C) --scan over T--> per-timestep layer sweep:
      conv : im2col (input loader, C5) -> (B*P, F) spike matrix
             fused_lif_gemm_int         -> Vmem' and output spikes
      fc   : flatten -> fused_lif_gemm_int
      pool : maxpool on the spike plane (binary in, binary out)
  readout: summed output spikes ("rate") or final-layer Vmem ("vmem")

Execution modes:
  * backend="fused" — the Pallas ``fused_lif_gemm_int`` kernel with
    tile-level zero-skipping (``interpret=True`` on CPU).
  * backend="jnp"   — pure-jnp composition of ``saturate`` +
    ``neuron_step_int``; the bit-exact oracle the fused path must match.

Chunked API (streaming): the engine's neuron state is first-class.
``init_state(engine, batch)`` returns an :class:`EngineState` (per-layer
integer Vmem carries, the readout accumulator, cumulative per-sample spike
statistics) and ``run_chunk(engine, state, events_chunk)`` advances it by
any number of timesteps, returning the new state plus a
:class:`ChunkOutput`.  Chunking is *exact*: for any partition of a stream
into chunks (including one timestep at a time) the final state and readout
are bit-identical to a single whole-stream call — the chip analogue is Vmem
staying resident in the CIM macro while events handshake in asynchronously.
``run_engine`` itself is just ``init_state`` + one ``run_chunk``.

Multi-core execution: ``compile_engine(engine, schedule)`` bakes a
``repro.compiler`` :class:`CoreSchedule` into the engine — every weight
layer's output channels become stacked per-core slices executed over a
``cores`` axis (``shard_map`` on a real device mesh, lockstep ``vmap``
emulation on one device) and reassembled by concatenation.  Because the
integer GEMM + neuron update are column-independent, the multi-core path
is bit-exact with the single-core path under any chunking, so the chunked
API below (and the streaming session manager on top of it) work unchanged
on a compiled plan.

Batch handling: the batch dimension is *folded into the GEMM rows*
(B output positions x P patches share one weight-stationary pass —
the TPU analogue of the macro's Vmem-pair weight reuse), or vmapped
per-sample with ``batch_mode="vmap"``.  Both produce identical spikes;
tests assert it.  Sharding the folded batch over a mesh data axis is a
``jax.device_put`` on ``events`` before calling — the engine is pure.

Everything is integer once weights are quantized: per-layer ``QuantSpec``
precision (W_b-bit weights, (2W-1)-bit Vmem), integer thresholds derived
from the float threshold and the layer's quantization scale.
``build_engine`` quantizes with per-tensor scales (scalar thresholds);
trained networks arrive through ``snn.export.deploy`` with per-channel
power-of-two scales and per-channel integer threshold vectors — both
execute on the same layer update, and the exported form is bit-identical
to the QAT training graph (``run_snn(mode="qat")``).

Memory: all readout/count accumulators are threaded through the scan
*carry* (O(1) in T), never recomputed from stacked per-timestep outputs —
a requirement for long-running streams (see the T=512 smoke test).  The
optional per-timestep count stacks in :class:`ChunkOutput` are O(chunk_T),
and can be disabled entirely with ``collect_counts=False``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..compiler.schedule import CoreSchedule
from ..core.layers import im2col, maxpool2d
from ..core.network import SNNSpec
from ..core.neuron import NeuronConfig, neuron_step_int
from ..core.quant import QuantSpec, quantize, saturate
from ..kernels.fused_lif_gemm import (
    DEFAULT_BLOCK,
    fused_lif_gemm_int,
    fused_lif_gemm_int_tblk,
)
from ..obs import trace as obs_trace

__all__ = [
    "ChunkOutput",
    "EngineConfig",
    "EngineOutput",
    "EngineState",
    "SNNEngine",
    "build_engine",
    "compile_engine",
    "init_state",
    "reset_slot",
    "run_chunk",
    "run_engine",
    "run_reference",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """How to execute the fused timestep loop."""

    qspec: QuantSpec
    backend: str = "fused"        # "fused" (Pallas) | "jnp" (oracle)
    interpret: bool = False       # Pallas interpret mode (CPU)
    skip_empty: bool = True       # tile-level zero-skipping
    block: tuple = DEFAULT_BLOCK
    # Vmem-stationary timestep tiling: >1 routes fused-backend chunks
    # through the layer-outer T_blk path (``fused_lif_gemm_int_tblk``) —
    # each weight block is touched once per ``t_block`` timesteps instead
    # of once per timestep.  Bit-exact with the scan path for any value.
    t_block: int = 1

    def __post_init__(self):
        assert self.backend in ("fused", "jnp"), self.backend
        assert isinstance(self.t_block, int) and self.t_block >= 1, \
            self.t_block


@dataclasses.dataclass(frozen=True)
class EngineLayer:
    """One weight layer compiled for the integer datapath."""

    kind: str                     # "conv" | "fc" | "pool" | "adaptive_pool"
    neuron: Optional[NeuronConfig] = None
    w_q: Optional[jax.Array] = None       # int8 quantized weights
    w_scale: Optional[object] = None      # scale (w ~= w_q * scale): float
                                          # (per-tensor) or (K,) array
                                          # (per-channel exported networks)
    thr_int: object = 0                   # integer threshold at this scale:
                                          # int, or (K,) int32 per-channel
    kh: int = 0
    kw: int = 0
    stride: int = 1
    padding: int = 0
    target_hw: int = 0                    # adaptive pool target
    # Multi-core placement (set by ``compile_engine`` from a CoreSchedule):
    # stacked per-core channel slices of ``w_q``, zero-padded to the widest
    # slice, plus each core's (lo, hi) channel range ((0, 0) = idle core).
    w_cores: Optional[jax.Array] = None   # (n_cores, F, Kc) int8
    core_slices: tuple = ()               # per-core (lo, hi), len n_cores
    # Per-core slices of a per-channel ``thr_int`` (padding gets v_max+1 so
    # padded channels never spike); None when ``thr_int`` is a scalar.
    thr_cores: Optional[jax.Array] = None  # (n_cores, Kc) int32
    # Autotuned kernel config override: (block_m, block_n, block_k, t_blk).
    # None falls back to the engine-wide ``cfg.block`` / ``cfg.t_block``.
    kcfg: Optional[tuple] = None


@dataclasses.dataclass(frozen=True)
class SNNEngine:
    spec: SNNSpec
    cfg: EngineConfig
    layers: tuple  # of EngineLayer
    # Multi-core plan (None = single-core).  ``compile_engine`` sets both;
    # ``device_parallel`` selects shard_map over a "cores" mesh axis (real
    # devices) vs lockstep vmap emulation (single device).
    schedule: Optional[CoreSchedule] = None
    device_parallel: bool = False


@dataclasses.dataclass
class EngineOutput:
    readout: jax.Array       # (B, classes) int32 rate counts or (B,H,W,C) Vmem
    spike_counts: jax.Array  # (T, n_weight_layers) output spikes per layer
    input_counts: jax.Array  # (T, n_weight_layers) input spikes per layer


@dataclasses.dataclass
class EngineState:
    """Persistent neuron state between chunks of one event stream batch.

    The streaming analogue of the chip keeping Vmem resident in the CIM
    macro across timesteps: everything a stream needs to resume exactly
    where it left off, and nothing that grows with the stream length.

    ``vmem``        per-layer int32 Vmem carries (None for pool layers),
                    batch-leading shapes ``(B, H, W, C)`` / ``(B, N)``.
    ``readout_acc`` cumulative readout: summed output spikes ("rate") or
                    the last weight layer's Vmem ("vmem").
    ``out_counts``  ``(n_weight_layers, B)`` cumulative output spikes.
    ``in_counts``   ``(n_weight_layers, B)`` cumulative input spikes.

    All accumulators are int32, like the rest of the integer datapath: a
    persistent "rate" stream wraps once any output unit or counter passes
    2^31 cumulative spikes.  At DVS-like rates that is hours of continuous
    streaming on one session — rotate (close/reopen) streams well before
    then; Vmem itself saturates at (2W−1) bits and never wraps.
    """

    vmem: tuple
    readout_acc: jax.Array
    out_counts: jax.Array
    in_counts: jax.Array


@dataclasses.dataclass
class ChunkOutput:
    """What one ``run_chunk`` call reports (alongside the new state).

    ``readout`` is the *cumulative* readout after the chunk (identical to
    ``state.readout_acc``).  The count fields are per-timestep stacks for
    this chunk only — ``(chunk_T, L)`` batch-summed and ``(chunk_T, L, B)``
    per-sample — or None under ``collect_counts=False``.  ``readouts`` is
    the per-timestep cumulative readout ``(chunk_T, B, ...)``, populated
    only under ``collect_readouts=True`` (the session manager uses it to
    read out a stream that ends mid-chunk).
    """

    readout: jax.Array
    spike_counts: Optional[jax.Array] = None
    input_counts: Optional[jax.Array] = None
    slot_spike_counts: Optional[jax.Array] = None
    slot_input_counts: Optional[jax.Array] = None
    readouts: Optional[jax.Array] = None


def build_engine(spec: SNNSpec, params, cfg: EngineConfig) -> SNNEngine:
    """Quantize float params into the integer engine (per-tensor scales)."""
    with obs_trace.default_tracer().span("engine.build", cat="compile",
                                         network=spec.name,
                                         backend=cfg.backend):
        return _build_engine(spec, params, cfg)


def _build_engine(spec: SNNSpec, params, cfg: EngineConfig) -> SNNEngine:
    layers = []
    for layer, p in zip(spec.layers, params):
        if layer.kind == "conv":
            w_q, scale = quantize(p, cfg.qspec)
            scale_f = float(scale)
            layers.append(EngineLayer(
                kind="conv",
                neuron=layer.conv.neuron,
                w_q=w_q,
                w_scale=scale_f,
                thr_int=int(round(layer.conv.neuron.threshold / scale_f)),
                kh=layer.conv.kh, kw=layer.conv.kw,
                stride=layer.conv.stride, padding=layer.conv.padding,
            ))
        elif layer.kind == "fc":
            w_q, scale = quantize(p, cfg.qspec)
            scale_f = float(scale)
            layers.append(EngineLayer(
                kind="fc",
                neuron=layer.fc.neuron,
                w_q=w_q,
                w_scale=scale_f,
                thr_int=int(round(layer.fc.neuron.threshold / scale_f)),
            ))
        elif layer.kind == "pool":
            layers.append(EngineLayer(kind="pool"))
        elif layer.kind == "adaptive_pool":
            layers.append(EngineLayer(kind="adaptive_pool",
                                      target_hw=layer.target_hw))
        else:  # pragma: no cover - spec is validated upstream
            raise ValueError(layer.kind)
    return SNNEngine(spec=spec, cfg=cfg, layers=tuple(layers))


# ---------------------------------------------------------------------------
# One fused layer-timestep.
# ---------------------------------------------------------------------------
def _fused_update(el: EngineLayer, s2: jax.Array, v2: jax.Array,
                  cfg: EngineConfig, w_q: Optional[jax.Array] = None,
                  thr=None):
    """(rows, F) spikes x (F, K) weights + (rows, K) Vmem -> (v', s).

    ``w_q``/``thr`` override the layer's weights and integer threshold —
    the multi-core path maps this function over per-core channel slices of
    the weight matrix (and, for per-channel-quantized layers, of the
    threshold vector).
    """
    n = el.neuron
    w = el.w_q if w_q is None else w_q
    thr = el.thr_int if thr is None else thr
    if cfg.backend == "fused":
        return fused_lif_gemm_int(
            s2, w, v2,
            threshold=thr,
            leak_shift=n.leak_shift if n.model == "lif" else 0,
            soft_reset=(n.reset == "soft"),
            vmem_bits=cfg.qspec.vmem_bits,
            block=cfg.block,
            interpret=cfg.interpret,
            skip_empty=cfg.skip_empty,
        )
    acc = jnp.dot(
        s2.astype(jnp.int32), w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    partial = saturate(acc, cfg.qspec)
    # leak_shift=0 means "no leak" (the kernels' convention); neuron_step_int
    # would compute v - (v >> 0) = 0, so route that case through IF dynamics.
    if n.model == "lif" and n.leak_shift == 0:
        n = dataclasses.replace(n, model="if")
    return neuron_step_int(v2, partial, n, cfg.qspec, thr)


# ---------------------------------------------------------------------------
# Multi-core execution (compiled CoreSchedule): each weight layer's output
# channels live as per-core slices.  Every core scans the full input spike
# plane into its own slice's weights (the spike-routing the cost model
# charges), so per-channel results are identical to the single-core GEMM —
# integer GEMM + neuron update are column-independent, which is what makes
# the reassembled output bit-exact.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _cores_mesh(n_cores: int) -> Mesh:
    """The ``cores`` device mesh axis (first ``n_cores`` local devices)."""
    return Mesh(np.array(jax.devices()[:n_cores]), ("cores",))


def _multicore_apply(el: EngineLayer, s2: jax.Array, v2: jax.Array,
                     cfg: EngineConfig, device_parallel: bool, core_update):
    """Run one layer's per-core channel slices and reassemble the output.

    ``el.w_cores`` is ``(C, F, Kc)``; core ``c`` computes channels
    ``[lo_c, hi_c)`` against the *same* spike matrix (replicated — the
    engine analogue of routing the input spikes to every consumer core).
    Idle cores carry zero-width slices padded with zero weights; their
    results are discarded at reassembly.

    ``core_update(sp, blocks)`` runs one core's slice, ``blocks`` =
    ``(w, [thr,] v)``, and returns a ``(v-like, s-like)`` pair whose
    *last* axis is the channel axis — the single-timestep update returns
    ``(rows, Kc)`` pairs, the T_blk update ``(T, rows, Kc)`` stacks; the
    slicing/reassembly below is rank-agnostic.
    """
    n_cores, _, kc = el.w_cores.shape

    def pad_slice(lo, hi):
        vc = v2[:, lo:hi]
        if hi - lo < kc:
            vc = jnp.pad(vc, ((0, 0), (0, kc - (hi - lo))))
        return vc

    # Per-core operands mapped over the ``cores`` axis: the weight slices,
    # plus (for per-channel-quantized layers) the threshold slices.  A
    # scalar threshold stays baked into the kernel via ``el.thr_int``.
    per_core_ops = [el.w_cores]
    if el.thr_cores is not None:
        per_core_ops.append(el.thr_cores)

    if device_parallel and n_cores > 1:
        # Full (n_cores, ...) stack: shard_map needs one uniform block per
        # mesh device, so idle cores ride along with zero weights (they are
        # idle silicon either way).
        v_cores = jnp.stack([pad_slice(lo, hi) for lo, hi in el.core_slices])
        fn = shard_map(
            lambda sp, *blocks: jax.vmap(
                lambda *bs: core_update(sp, bs))(*blocks),
            mesh=_cores_mesh(n_cores),
            in_specs=(P(),) + (P("cores"),) * (len(per_core_ops) + 1),
            out_specs=(P("cores"), P("cores")),
            check_rep=False,
        )
        v_next, s = fn(s2, *per_core_ops, v_cores)
        row = {c: c for c in range(n_cores)}
    else:
        # Lockstep vmapped emulation on one device: only the cores that
        # actually hold a slice compute — a whole layer placed on one core
        # must not cost n_cores zero-weight GEMMs.
        active = tuple(c for c in range(n_cores)
                       if el.core_slices[c][1] > el.core_slices[c][0])
        idx = np.asarray(active)
        v_cores = jnp.stack([pad_slice(*el.core_slices[c]) for c in active])
        v_next, s = jax.vmap(lambda *bs: core_update(s2, bs))(
            *[op[idx] for op in per_core_ops], v_cores)
        row = {c: i for i, c in enumerate(active)}

    # Reassemble output channels in slice order (slices are contiguous and
    # cover [0, K), so concatenation restores the single-core layout).
    # ``[..., :width]`` / ``axis=-1`` keep this correct for both the 2-D
    # single-timestep outputs and the 3-D T_blk trajectory stacks.
    order = sorted(
        (c for c in row if el.core_slices[c][1] > el.core_slices[c][0]),
        key=lambda c: el.core_slices[c][0],
    )
    v_out = jnp.concatenate(
        [v_next[row[c]][..., : el.core_slices[c][1] - el.core_slices[c][0]]
         for c in order], axis=-1)
    s_out = jnp.concatenate(
        [s[row[c]][..., : el.core_slices[c][1] - el.core_slices[c][0]]
         for c in order], axis=-1)
    return v_out, s_out


def _multicore_update(el: EngineLayer, s2: jax.Array, v2: jax.Array,
                      cfg: EngineConfig, device_parallel: bool):
    """Single-timestep multi-core layer update (the original path)."""
    def core_update(sp, blocks):
        w, *thr, v = blocks
        return _fused_update(el, sp, v, cfg, w_q=w,
                             thr=thr[0] if thr else None)

    return _multicore_apply(el, s2, v2, cfg, device_parallel, core_update)


def _layer_update(engine: SNNEngine, el: EngineLayer, s2: jax.Array,
                  v2: jax.Array):
    if el.w_cores is not None:
        return _multicore_update(el, s2, v2, engine.cfg,
                                 engine.device_parallel)
    return _fused_update(el, s2, v2, engine.cfg)


# ---------------------------------------------------------------------------
# Vmem-stationary T_blk tiling: the layer-outer chunk path.  Instead of
# scanning timesteps with a full layer sweep per step, each weight layer
# consumes the whole chunk as (T, rows, F) spike stacks in T_blk-sized
# slabs — one ``fused_lif_gemm_int_tblk`` call per slab touches every
# weight block once for the slab's timesteps (the chip's Vmem-stationary
# mode 2 reuse, Sec II-E).  Bit-exact with the scan path because integer
# accumulation is exact and the per-slab neuron program is sequential in t.
# ---------------------------------------------------------------------------
def _layer_kcfg(el: EngineLayer, cfg: EngineConfig):
    """(gemm block, t_blk) for one layer: autotuned override or config."""
    if el.kcfg is not None:
        bm, bn, bk, tb = el.kcfg
        return (bm, bn, bk), tb
    return cfg.block, cfg.t_block


def _tblk_update(el: EngineLayer, s_slab: jax.Array, v2: jax.Array,
                 cfg: EngineConfig, block: tuple,
                 w_q: Optional[jax.Array] = None, thr=None):
    """One T_blk slab: (T, rows, F) spikes -> (T, rows, K) v-traj + spikes."""
    n = el.neuron
    w = el.w_q if w_q is None else w_q
    thr = el.thr_int if thr is None else thr
    return fused_lif_gemm_int_tblk(
        s_slab, w, v2,
        threshold=thr,
        leak_shift=n.leak_shift if n.model == "lif" else 0,
        soft_reset=(n.reset == "soft"),
        vmem_bits=cfg.qspec.vmem_bits,
        block=block,
        interpret=cfg.interpret,
        skip_empty=cfg.skip_empty,
    )


def _layer_update_tblk(engine: SNNEngine, el: EngineLayer,
                       s_stack: jax.Array, v2: jax.Array):
    """Walk a (T, rows, F) spike stack through one layer in T_blk slabs.

    ``chunk_T`` need not divide ``t_blk``: the remainder slab is simply a
    second (static-shape) kernel specialization.  The Vmem carry threads
    through the slabs, so the result is bit-exact under any slab geometry.
    """
    cfg = engine.cfg
    block, tb = _layer_kcfg(el, cfg)
    t = s_stack.shape[0]

    def slab_update(slab, v_in):
        if el.w_cores is None:
            return _tblk_update(el, slab, v_in, cfg, block)

        def core_update(sp, blocks):
            w, *thr, v = blocks
            return _tblk_update(el, sp, v, cfg, block, w_q=w,
                                thr=thr[0] if thr else None)

        return _multicore_apply(el, slab, v_in, cfg,
                                engine.device_parallel, core_update)

    v_parts, s_parts = [], []
    for t0 in range(0, t, tb):
        v_traj, s = slab_update(s_stack[t0:t0 + tb], v2)
        v_parts.append(v_traj)
        s_parts.append(s)
        v2 = v_traj[-1]
    if len(v_parts) == 1:
        return v_parts[0], s_parts[0]
    return jnp.concatenate(v_parts), jnp.concatenate(s_parts)


def _tblk_active(engine: SNNEngine) -> bool:
    """Route chunks through the layer-outer tiled path?"""
    if engine.cfg.backend != "fused":
        return False
    if engine.cfg.t_block > 1:
        return True
    return any(el.kcfg is not None and el.kcfg[3] > 1
               for el in engine.layers if el.kind in ("conv", "fc"))


def _pool_stack(act: jax.Array, window: int, stride: int) -> jax.Array:
    """maxpool2d over a (T, B, H, W, C) stack via T*B folding."""
    t, b = act.shape[:2]
    out = maxpool2d(act.reshape((t * b,) + act.shape[2:]),
                    window=window, stride=stride)
    return out.reshape((t, b) + out.shape[1:])


def _run_chunk_tiled(engine: SNNEngine, state: EngineState,
                     events: jax.Array, collect_counts: bool,
                     collect_readouts: bool):
    """Layer-outer twin of ``run_chunk``'s scan: same state, same outputs.

    Memory note: this path materializes (chunk_T, ...) activation stacks
    per layer — O(chunk_T), like ``collect_counts`` — so streams should
    keep ``chunk_T`` at a small multiple of ``t_block`` (the scan path
    remains the right tool for huge single-chunk runs).
    """
    spec = engine.spec
    t, b = events.shape[:2]
    act = events.astype(jnp.float32)
    new_vmem, counts_out, counts_in = [], [], []
    last = None  # (v_traj, s_stack) of the last weight layer
    for el, v in zip(engine.layers, state.vmem):
        if el.kind == "conv":
            counts_in.append(jnp.sum(act != 0, axis=(2, 3, 4)))
            flat = act.reshape((t * b,) + act.shape[2:])
            cols = im2col(flat, el.kh, el.kw, el.stride, el.padding)
            p, f = cols.shape[1], cols.shape[2]
            k = el.w_q.shape[1]
            s_stack = cols.reshape(t, b * p, f).astype(jnp.int8)
            v_traj, s = _layer_update_tblk(engine, el, s_stack,
                                           v.reshape(b * p, k))
            v_traj = v_traj.reshape((t,) + v.shape)
            s = s.reshape((t,) + v.shape)
            new_vmem.append(v_traj[-1])
            counts_out.append(jnp.sum(s, axis=(2, 3, 4)))
            act, last = s.astype(jnp.float32), (v_traj, s)
        elif el.kind == "fc":
            flat = act.reshape(t, b, -1)
            counts_in.append(jnp.sum(flat != 0, axis=2))
            v_traj, s = _layer_update_tblk(engine, el,
                                           flat.astype(jnp.int8), v)
            new_vmem.append(v_traj[-1])
            counts_out.append(jnp.sum(s, axis=2))
            act, last = s.astype(jnp.float32), (v_traj, s)
        elif el.kind == "pool":
            act = _pool_stack(act, 2, 2)
            new_vmem.append(None)
        elif el.kind == "adaptive_pool":
            kk = act.shape[2] // el.target_hw
            act = _pool_stack(act, kk, kk)
            new_vmem.append(None)
    v_traj, s_last = last
    if spec.readout == "rate":
        accs = state.readout_acc[None] + jnp.cumsum(s_last, axis=0)
    else:
        accs = v_traj
    slot_out = jnp.stack(counts_out, axis=1)   # (chunk_T, L, B)
    slot_in = jnp.stack(counts_in, axis=1)
    new_state = EngineState(
        vmem=tuple(new_vmem),
        readout_acc=accs[-1],
        out_counts=state.out_counts + jnp.sum(slot_out, axis=0),
        in_counts=state.in_counts + jnp.sum(slot_in, axis=0),
    )
    return new_state, ChunkOutput(
        readout=accs[-1],
        spike_counts=jnp.sum(slot_out, axis=2) if collect_counts else None,
        input_counts=jnp.sum(slot_in, axis=2) if collect_counts else None,
        slot_spike_counts=slot_out if collect_counts else None,
        slot_input_counts=slot_in if collect_counts else None,
        readouts=accs if collect_readouts else None,
    )


def compile_engine(engine: SNNEngine, schedule: CoreSchedule,
                   device_parallel: Optional[bool] = None) -> SNNEngine:
    """Bake a compiler :class:`CoreSchedule` into an executable engine.

    Splits every weight layer's quantized weights into the schedule's
    per-core channel slices (stacked, zero-padded to the widest slice) and
    returns an engine whose ``run_chunk``/``run_engine`` execute the
    multi-core plan — bit-exactly with the single-core engine, under any
    chunking, so the streaming session manager works unchanged.

    ``device_parallel=None`` auto-selects: ``shard_map`` over a ``cores``
    mesh axis when the host has at least ``n_cores`` devices, lockstep
    ``vmap`` emulation otherwise.
    """
    with obs_trace.default_tracer().span("engine.compile_schedule",
                                         cat="compile",
                                         network=engine.spec.name,
                                         n_cores=schedule.n_cores):
        return _compile_engine(engine, schedule, device_parallel)


def _compile_engine(engine: SNNEngine, schedule: CoreSchedule,
                    device_parallel: Optional[bool] = None) -> SNNEngine:
    assert engine.schedule is None, "engine already carries a schedule"
    for ls in schedule.layers:
        if ls.plan.spec != engine.cfg.qspec:
            raise ValueError(
                f"schedule selected {ls.plan.spec} for layer {ls.node} but "
                f"the engine executes {engine.cfg.qspec}; precision-"
                "exploring schedules (allowed_specs) are for cost analysis, "
                "not execution")
    n_cores = schedule.n_cores
    by_node = {ls.node: ls for ls in schedule.layers}
    new_layers = []
    for idx, el in enumerate(engine.layers):
        if el.kind not in ("conv", "fc"):
            new_layers.append(el)
            continue
        ls = by_node[idx]
        k = el.w_q.shape[1]
        assert k == ls.out_channels, (k, ls.out_channels)
        kc = max(s.width for s in ls.slices)
        w_cores = np.zeros((n_cores, el.w_q.shape[0], kc), np.int8)
        core_slices = [(0, 0)] * n_cores
        w_np = np.asarray(el.w_q)
        # Per-channel-quantized layers carry their threshold vector along
        # the same channel slices; padding gets v_max+1 (never fires).
        per_channel = np.ndim(el.thr_int) > 0
        thr_cores = np.full((n_cores, kc), engine.cfg.qspec.v_max + 1,
                            np.int32) if per_channel else None
        for s in ls.slices:
            w_cores[s.core, :, : s.width] = w_np[:, s.lo:s.hi]
            core_slices[s.core] = (s.lo, s.hi)
            if per_channel:
                thr_cores[s.core, : s.width] = np.asarray(
                    el.thr_int)[s.lo:s.hi]
        new_layers.append(dataclasses.replace(
            el, w_cores=jnp.asarray(w_cores), core_slices=tuple(core_slices),
            thr_cores=None if thr_cores is None else jnp.asarray(thr_cores)))
    if device_parallel is None:
        device_parallel = 1 < n_cores <= len(jax.devices())
    if device_parallel:
        assert n_cores <= len(jax.devices()), (
            f"device_parallel needs {n_cores} devices, "
            f"host has {len(jax.devices())}")
    return dataclasses.replace(engine, layers=tuple(new_layers),
                               schedule=schedule,
                               device_parallel=bool(device_parallel))


def _forward_t(engine: SNNEngine, state, x_t):
    """One timestep through every layer.

    Returns ``(state', out, counts_out, counts_in)`` with *per-sample*
    counts of shape ``(n_weight_layers, B)`` — the batch axis is kept so a
    streaming session can attribute spikes (and therefore chip cost) to the
    individual stream occupying each batch slot.
    """
    act = x_t  # float {0,1} spike plane (im2col needs float)
    new_state, counts_out, counts_in, out = [], [], [], None
    for el, v in zip(engine.layers, state):
        if el.kind == "conv":
            b = act.shape[0]
            counts_in.append(jnp.sum(act != 0, axis=(1, 2, 3)))
            cols = im2col(act, el.kh, el.kw, el.stride, el.padding)  # (B,P,F)
            rows, f = b * cols.shape[1], cols.shape[2]
            k = el.w_q.shape[1]
            v_next, s = _layer_update(
                engine, el, cols.reshape(rows, f).astype(jnp.int8),
                v.reshape(rows, k),
            )
            v_next = v_next.reshape(v.shape)
            s = s.reshape(v.shape)
            new_state.append(v_next)
            counts_out.append(jnp.sum(s, axis=(1, 2, 3)))
            act, out = s.astype(jnp.float32), (v_next, s)
        elif el.kind == "fc":
            flat = act.reshape(act.shape[0], -1)
            counts_in.append(jnp.sum(flat != 0, axis=1))
            v_next, s = _layer_update(engine, el, flat.astype(jnp.int8), v)
            new_state.append(v_next)
            counts_out.append(jnp.sum(s, axis=1))
            act, out = s.astype(jnp.float32), (v_next, s)
        elif el.kind == "pool":
            act = maxpool2d(act)
            new_state.append(None)
        elif el.kind == "adaptive_pool":
            hw = act.shape[1]
            kk = hw // el.target_hw
            act = maxpool2d(act, window=kk, stride=kk)
            new_state.append(None)
    return new_state, out, jnp.stack(counts_out), jnp.stack(counts_in)


def _init_vmem(engine: SNNEngine, batch: int):
    """Integer Vmem carries (network's float shape walk, cast to int32)."""
    from ..core.network import _init_state as _float_state

    return [
        None if s is None else s.astype(jnp.int32)
        for s in _float_state(engine.spec, batch)
    ]


def _n_weight_layers(engine: SNNEngine) -> int:
    return sum(1 for el in engine.layers if el.kind in ("conv", "fc"))


def init_state(engine: SNNEngine, batch: int) -> EngineState:
    """Fresh (all-zero) persistent state for ``batch`` concurrent streams."""
    spec = engine.spec
    vmem = _init_vmem(engine, batch)
    if spec.readout == "rate":
        acc0 = jnp.zeros((batch, spec.layers[-1].c_out), jnp.int32)
    else:
        # Vmem readout: the accumulator is the last weight layer's Vmem,
        # whose spatial shape reflects any pooling/striding along the way.
        acc0 = jnp.zeros_like(
            next(s for s in reversed(vmem) if s is not None))
    n_l = _n_weight_layers(engine)
    return EngineState(
        vmem=tuple(vmem),
        readout_acc=acc0,
        out_counts=jnp.zeros((n_l, batch), jnp.int32),
        in_counts=jnp.zeros((n_l, batch), jnp.int32),
    )


def reset_slot(state: EngineState, slot) -> EngineState:
    """Zero one batch slot's state, leaving every other slot untouched.

    This is slot retirement for continuous batching: the retired stream's
    Vmem, readout and counters are cleared so the next stream admitted into
    the slot starts from ``init_state`` conditions, and so the slot's
    all-zero spike planes feed the zero-skip path until then.  ``slot`` may
    be a traced int32 — the update is a pure scatter, safe under ``jit``.
    """
    return EngineState(
        vmem=tuple(None if v is None else v.at[slot].set(0)
                   for v in state.vmem),
        readout_acc=state.readout_acc.at[slot].set(0),
        out_counts=state.out_counts.at[:, slot].set(0),
        in_counts=state.in_counts.at[:, slot].set(0),
    )


def run_chunk(
    engine: SNNEngine,
    state: EngineState,
    events: jax.Array,           # (chunk_T, B, H, W, C) binary
    collect_counts: bool = True,
    collect_readouts: bool = False,
) -> tuple:
    """Advance ``state`` by one chunk of timesteps; returns ``(state', out)``.

    Bit-exact under any chunking: ``run_chunk`` over consecutive chunks of
    a stream produces the same final state/readout as one call over the
    concatenated stream.  All accumulators live in the scan *carry* — O(1)
    memory in the total stream length; the optional per-timestep stacks in
    the returned :class:`ChunkOutput` are O(chunk_T) and can be switched
    off for long whole-stream runs (``collect_counts=False``).
    """
    assert events.ndim == 5, "expected (chunk_T, B, H, W, C)"
    spec = engine.spec
    if _tblk_active(engine):
        return _run_chunk_tiled(engine, state, events,
                                collect_counts, collect_readouts)

    def step(carry, x_t):
        vmem, acc, oc, ic = carry
        vmem, (v, s), c_out, c_in = _forward_t(engine, list(vmem), x_t)
        acc = acc + s if spec.readout == "rate" else v
        carry = (tuple(vmem), acc, oc + c_out, ic + c_in)
        ys = (
            (c_out, c_in) if collect_counts else None,
            acc if collect_readouts else None,
        )
        return carry, ys

    carry0 = (state.vmem, state.readout_acc, state.out_counts,
              state.in_counts)
    (vmem, acc, oc, ic), (counts, accs) = jax.lax.scan(step, carry0, events)
    new_state = EngineState(vmem=vmem, readout_acc=acc,
                            out_counts=oc, in_counts=ic)
    slot_out = slot_in = None
    sum_out = sum_in = None
    if collect_counts:
        slot_out, slot_in = counts            # (chunk_T, L, B)
        sum_out = jnp.sum(slot_out, axis=2)   # (chunk_T, L)
        sum_in = jnp.sum(slot_in, axis=2)
    return new_state, ChunkOutput(
        readout=acc,
        spike_counts=sum_out,
        input_counts=sum_in,
        slot_spike_counts=slot_out,
        slot_input_counts=slot_in,
        readouts=accs,
    )


def _run_folded(engine: SNNEngine, events: jax.Array) -> EngineOutput:
    state = init_state(engine, events.shape[1])
    _, out = run_chunk(engine, state, events)
    return EngineOutput(readout=out.readout, spike_counts=out.spike_counts,
                        input_counts=out.input_counts)


def run_engine(engine: SNNEngine, events: jax.Array,
               batch_mode: str = "fold") -> EngineOutput:
    """Run a whole (T, B, H, W, C) binary event stream through the engine.

    ``batch_mode="fold"`` folds B into the GEMM row dimension (one big
    weight-stationary pass per layer-timestep); ``"vmap"`` maps a
    single-sample engine over the batch axis.  Identical results.

    Implemented as ``init_state`` + one whole-stream ``run_chunk`` — the
    chunked/streaming path and the batch path are the same code.
    """
    assert events.ndim == 5, "expected (T, B, H, W, C)"
    if batch_mode == "fold":
        return _run_folded(engine, events)
    if batch_mode == "vmap":
        out = jax.vmap(
            lambda ev: _run_folded(engine, ev[:, None]),
            in_axes=1,
        )(events)
        return EngineOutput(
            readout=out.readout[:, 0],
            spike_counts=jnp.sum(out.spike_counts, axis=0),
            input_counts=jnp.sum(out.input_counts, axis=0),
        )
    raise ValueError(f"unknown batch_mode {batch_mode!r}")


jax.tree_util.register_pytree_node(
    EngineOutput,
    lambda o: ((o.readout, o.spike_counts, o.input_counts), None),
    lambda _, leaves: EngineOutput(*leaves),
)

jax.tree_util.register_pytree_node(
    EngineState,
    lambda st: ((st.vmem, st.readout_acc, st.out_counts, st.in_counts), None),
    lambda _, leaves: EngineState(*leaves),
)

jax.tree_util.register_pytree_node(
    ChunkOutput,
    lambda o: ((o.readout, o.spike_counts, o.input_counts,
                o.slot_spike_counts, o.slot_input_counts, o.readouts), None),
    lambda _, leaves: ChunkOutput(*leaves),
)


# ---------------------------------------------------------------------------
# Pure-jnp per-timestep reference (no scan, no Pallas): the ground truth the
# engine must reproduce spike-for-spike.
# ---------------------------------------------------------------------------
def run_reference(engine: SNNEngine, events) -> EngineOutput:
    """Python-loop integer reference over the same quantized parameters."""
    spec = engine.spec
    cfg = dataclasses.replace(engine.cfg, backend="jnp")
    ref_engine = dataclasses.replace(engine, cfg=cfg)
    batch = events.shape[1]
    state = _init_vmem(ref_engine, batch)
    acc = None
    all_out, all_in = [], []
    for t in range(events.shape[0]):
        state, (v, s), c_out, c_in = _forward_t(ref_engine, state, events[t])
        if spec.readout == "rate":
            acc = s if acc is None else acc + s
        else:
            acc = v
        all_out.append(jnp.sum(c_out, axis=1))
        all_in.append(jnp.sum(c_in, axis=1))
    return EngineOutput(
        readout=acc,
        spike_counts=jnp.stack(all_out),
        input_counts=jnp.stack(all_in),
    )
