"""Batched, multi-timestep SNN inference engine (fused timestep loop).

This is the path from a DVS event tensor to output spike counts that the
chip actually takes: every timestep, every layer, weight->Vmem accumulation
fused with the neuron update, state carried across timesteps.  The seed repo
modeled one macro drain / one GEMM at a time; the engine closes the loop:

  events (T, B, H, W, C) --scan over T--> per-timestep layer sweep:
      conv : im2col (input loader, C5) -> (B*P, F) spike matrix
             fused_lif_gemm_int         -> Vmem' and output spikes
      fc   : flatten -> fused_lif_gemm_int
      pool : maxpool on the spike plane (binary in, binary out)
  readout: summed output spikes ("rate") or final-layer Vmem ("vmem")

Execution modes:
  * backend="fused" — the Pallas ``fused_lif_gemm_int`` kernel with
    tile-level zero-skipping (``interpret=True`` on CPU).
  * backend="jnp"   — pure-jnp composition of ``saturate`` +
    ``neuron_step_int``; the bit-exact oracle the fused path must match.

Batch handling: the batch dimension is *folded into the GEMM rows*
(B output positions x P patches share one weight-stationary pass —
the TPU analogue of the macro's Vmem-pair weight reuse), or vmapped
per-sample with ``batch_mode="vmap"``.  Both produce identical spikes;
tests assert it.  Sharding the folded batch over a mesh data axis is a
``jax.device_put`` on ``events`` before calling — the engine is pure.

Everything is integer once weights are quantized: per-layer ``QuantSpec``
precision (W_b-bit weights, (2W-1)-bit Vmem), integer thresholds derived
from the float threshold and the layer's quantization scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.layers import im2col, maxpool2d
from ..core.network import SNNSpec
from ..core.neuron import NeuronConfig, neuron_step_int
from ..core.quant import QuantSpec, quantize, saturate
from ..kernels.fused_lif_gemm import DEFAULT_BLOCK, fused_lif_gemm_int

__all__ = [
    "EngineConfig",
    "EngineOutput",
    "SNNEngine",
    "build_engine",
    "run_engine",
    "run_reference",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """How to execute the fused timestep loop."""

    qspec: QuantSpec
    backend: str = "fused"        # "fused" (Pallas) | "jnp" (oracle)
    interpret: bool = False       # Pallas interpret mode (CPU)
    skip_empty: bool = True       # tile-level zero-skipping
    block: tuple = DEFAULT_BLOCK

    def __post_init__(self):
        assert self.backend in ("fused", "jnp"), self.backend


@dataclasses.dataclass(frozen=True)
class EngineLayer:
    """One weight layer compiled for the integer datapath."""

    kind: str                     # "conv" | "fc" | "pool" | "adaptive_pool"
    neuron: Optional[NeuronConfig] = None
    w_q: Optional[jax.Array] = None       # int8 quantized weights
    w_scale: Optional[float] = None       # float scale (w ~= w_q * scale)
    thr_int: int = 0                      # integer threshold at this scale
    kh: int = 0
    kw: int = 0
    stride: int = 1
    padding: int = 0
    target_hw: int = 0                    # adaptive pool target


@dataclasses.dataclass(frozen=True)
class SNNEngine:
    spec: SNNSpec
    cfg: EngineConfig
    layers: tuple  # of EngineLayer


@dataclasses.dataclass
class EngineOutput:
    readout: jax.Array       # (B, classes) int32 rate counts or (B,H,W,C) Vmem
    spike_counts: jax.Array  # (T, n_weight_layers) output spikes per layer
    input_counts: jax.Array  # (T, n_weight_layers) input spikes per layer


def build_engine(spec: SNNSpec, params, cfg: EngineConfig) -> SNNEngine:
    """Quantize float params into the integer engine (per-tensor scales)."""
    layers = []
    for layer, p in zip(spec.layers, params):
        if layer.kind == "conv":
            w_q, scale = quantize(p, cfg.qspec)
            scale_f = float(scale)
            layers.append(EngineLayer(
                kind="conv",
                neuron=layer.conv.neuron,
                w_q=w_q,
                w_scale=scale_f,
                thr_int=int(round(layer.conv.neuron.threshold / scale_f)),
                kh=layer.conv.kh, kw=layer.conv.kw,
                stride=layer.conv.stride, padding=layer.conv.padding,
            ))
        elif layer.kind == "fc":
            w_q, scale = quantize(p, cfg.qspec)
            scale_f = float(scale)
            layers.append(EngineLayer(
                kind="fc",
                neuron=layer.fc.neuron,
                w_q=w_q,
                w_scale=scale_f,
                thr_int=int(round(layer.fc.neuron.threshold / scale_f)),
            ))
        elif layer.kind == "pool":
            layers.append(EngineLayer(kind="pool"))
        elif layer.kind == "adaptive_pool":
            layers.append(EngineLayer(kind="adaptive_pool",
                                      target_hw=layer.target_hw))
        else:  # pragma: no cover - spec is validated upstream
            raise ValueError(layer.kind)
    return SNNEngine(spec=spec, cfg=cfg, layers=tuple(layers))


# ---------------------------------------------------------------------------
# One fused layer-timestep.
# ---------------------------------------------------------------------------
def _fused_update(el: EngineLayer, s2: jax.Array, v2: jax.Array,
                  cfg: EngineConfig):
    """(rows, F) spikes x (F, K) weights + (rows, K) Vmem -> (v', s)."""
    n = el.neuron
    if cfg.backend == "fused":
        return fused_lif_gemm_int(
            s2, el.w_q, v2,
            threshold=el.thr_int,
            leak_shift=n.leak_shift if n.model == "lif" else 0,
            soft_reset=(n.reset == "soft"),
            vmem_bits=cfg.qspec.vmem_bits,
            block=cfg.block,
            interpret=cfg.interpret,
            skip_empty=cfg.skip_empty,
        )
    acc = jnp.dot(
        s2.astype(jnp.int32), el.w_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    partial = saturate(acc, cfg.qspec)
    # leak_shift=0 means "no leak" (the kernels' convention); neuron_step_int
    # would compute v - (v >> 0) = 0, so route that case through IF dynamics.
    if n.model == "lif" and n.leak_shift == 0:
        n = dataclasses.replace(n, model="if")
    return neuron_step_int(v2, partial, n, cfg.qspec, el.thr_int)


def _forward_t(engine: SNNEngine, state, x_t):
    """One timestep through every layer. Returns (state', out, in/out counts)."""
    cfg = engine.cfg
    act = x_t  # float {0,1} spike plane (im2col needs float)
    new_state, counts_out, counts_in, out = [], [], [], None
    for el, v in zip(engine.layers, state):
        if el.kind == "conv":
            b = act.shape[0]
            counts_in.append(jnp.sum(act != 0))
            cols = im2col(act, el.kh, el.kw, el.stride, el.padding)  # (B,P,F)
            rows, f = b * cols.shape[1], cols.shape[2]
            k = el.w_q.shape[1]
            v_next, s = _fused_update(
                el, cols.reshape(rows, f).astype(jnp.int8),
                v.reshape(rows, k), cfg,
            )
            v_next = v_next.reshape(v.shape)
            s = s.reshape(v.shape)
            new_state.append(v_next)
            counts_out.append(jnp.sum(s))
            act, out = s.astype(jnp.float32), (v_next, s)
        elif el.kind == "fc":
            flat = act.reshape(act.shape[0], -1)
            counts_in.append(jnp.sum(flat != 0))
            v_next, s = _fused_update(el, flat.astype(jnp.int8), v, cfg)
            new_state.append(v_next)
            counts_out.append(jnp.sum(s))
            act, out = s.astype(jnp.float32), (v_next, s)
        elif el.kind == "pool":
            act = maxpool2d(act)
            new_state.append(None)
        elif el.kind == "adaptive_pool":
            hw = act.shape[1]
            kk = hw // el.target_hw
            act = maxpool2d(act, window=kk, stride=kk)
            new_state.append(None)
    return new_state, out, jnp.stack(counts_out), jnp.stack(counts_in)


def _init_state(engine: SNNEngine, batch: int):
    """Integer Vmem carries (network's float shape walk, cast to int32)."""
    from ..core.network import _init_state as _float_state

    return [
        None if s is None else s.astype(jnp.int32)
        for s in _float_state(engine.spec, batch)
    ]


def _run_folded(engine: SNNEngine, events: jax.Array) -> EngineOutput:
    spec = engine.spec
    batch = events.shape[1]
    state0 = _init_state(engine, batch)
    n_out = spec.layers[-1].c_out

    def step(carry, x_t):
        state, acc = carry
        state, (v, s), c_out, c_in = _forward_t(engine, state, x_t)
        acc = acc + s if spec.readout == "rate" else v
        return (state, acc), (c_out, c_in)

    if spec.readout == "rate":
        acc0 = jnp.zeros((batch, n_out), jnp.int32)
    else:
        # Vmem readout: the carry is the last weight layer's Vmem, whose
        # spatial shape reflects any pooling/striding along the way.
        acc0 = jnp.zeros_like(
            next(s for s in reversed(state0) if s is not None))
    (_, acc), (c_out, c_in) = jax.lax.scan(step, (state0, acc0), events)
    return EngineOutput(readout=acc, spike_counts=c_out, input_counts=c_in)


def run_engine(engine: SNNEngine, events: jax.Array,
               batch_mode: str = "fold") -> EngineOutput:
    """Run a whole (T, B, H, W, C) binary event stream through the engine.

    ``batch_mode="fold"`` folds B into the GEMM row dimension (one big
    weight-stationary pass per layer-timestep); ``"vmap"`` maps a
    single-sample engine over the batch axis.  Identical results.
    """
    assert events.ndim == 5, "expected (T, B, H, W, C)"
    if batch_mode == "fold":
        return _run_folded(engine, events)
    if batch_mode == "vmap":
        out = jax.vmap(
            lambda ev: _run_folded(engine, ev[:, None]),
            in_axes=1,
        )(events)
        return EngineOutput(
            readout=out.readout[:, 0],
            spike_counts=jnp.sum(out.spike_counts, axis=0),
            input_counts=jnp.sum(out.input_counts, axis=0),
        )
    raise ValueError(f"unknown batch_mode {batch_mode!r}")


jax.tree_util.register_pytree_node(
    EngineOutput,
    lambda o: ((o.readout, o.spike_counts, o.input_counts), None),
    lambda _, leaves: EngineOutput(*leaves),
)


# ---------------------------------------------------------------------------
# Pure-jnp per-timestep reference (no scan, no Pallas): the ground truth the
# engine must reproduce spike-for-spike.
# ---------------------------------------------------------------------------
def run_reference(engine: SNNEngine, events) -> EngineOutput:
    """Python-loop integer reference over the same quantized parameters."""
    spec = engine.spec
    cfg = dataclasses.replace(engine.cfg, backend="jnp")
    ref_engine = dataclasses.replace(engine, cfg=cfg)
    batch = events.shape[1]
    state = _init_state(ref_engine, batch)
    acc = None
    all_out, all_in = [], []
    for t in range(events.shape[0]):
        state, (v, s), c_out, c_in = _forward_t(ref_engine, state, events[t])
        if spec.readout == "rate":
            acc = s if acc is None else acc + s
        else:
            acc = v
        all_out.append(c_out)
        all_in.append(c_in)
    return EngineOutput(
        readout=acc,
        spike_counts=jnp.stack(all_out),
        input_counts=jnp.stack(all_in),
    )
