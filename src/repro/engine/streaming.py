"""Streaming stateful serving: persistent-Vmem sessions over one batched step.

SpiDR's defining behavior is that a layer's membrane potentials never leave
the CIM macro between timesteps — events handshake in asynchronously and
accumulate into *resident* state.  This module is the serving-system
analogue: a :class:`StreamSessionManager` keeps an :class:`EngineState`
whose batch axis is a bank of ``capacity`` *slots*, each slot holding the
persistent Vmem of one live event stream, and multiplexes every live
stream's next chunk of timesteps into **one fixed-shape batched
``run_chunk``** per tick (shapes never change, so the jitted step never
recompiles — the SNN analogue of the continuous-batching decode loop in
``launch/serve.py``).

Slot lifecycle (continuous batching over neuron state instead of KV cache):

  open()   -> allocate a free slot, zero its state (``reset_slot``)
  step()   -> pack each live stream's chunk into (chunk_T, capacity, H, W, C)
              — slots without a stream (or whose stream ended) contribute
              all-zero event planes, which the kernels' tile-level zero-skip
              eliminates — then advance every slot in one ``run_chunk``
  close()  -> retire the slot: zero its state so it is inert until reuse

Per-slot accounting rides on the engine's per-sample spike counters: each
tick, every *active* slot's ``(chunk_T, n_layers)`` input-spike counts are
priced with ``engine/cost.py`` (async-pipeline cycles + calibrated energy)
and accumulated on the slot.  Inactive slots are never charged — their
event planes are all zero, they contribute no spikes, and their cumulative
cycle/energy stays exactly 0.

Exactness contract (tested): because batch slots never interact inside the
engine (GEMM rows are independent, pooling is per-sample), a stream served
through the manager — whatever the chunk size, whatever else shares the
batch, however often slots around it are retired and reused — produces
spikes and readouts bit-identical to a single whole-stream ``run_engine``
call on that stream alone.

Multi-core plans ride through unchanged: an engine compiled with a
``repro.compiler`` CoreSchedule (``engine.compile_engine``) has the same
``run_chunk`` signature and bit-exact outputs, so the session mechanics
above don't change at all — only the pricing switches to
``estimate_multicore_cost`` (one resumable handshake clock set per core
per slot, additive routing cycles), and each ``SlotUpdate`` additionally
carries the stream's cumulative per-core cycles and load imbalance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pipeline import PipelineState
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .cost import estimate_cost, estimate_multicore_cost
from .inference import SNNEngine, init_state, reset_slot, run_chunk

__all__ = ["SESSION_SCHEMA_VERSION", "SlotUpdate", "StreamSessionManager"]

# Serialized-session schema version (see ``StreamSessionManager.state_dict``).
# Bump when the snapshot layout changes; ``load_state_dict`` refuses newer
# schemas with a clean error instead of misreading them.
SESSION_SCHEMA_VERSION = 1


@dataclasses.dataclass
class SlotUpdate:
    """Incremental reply for one stream after one session tick."""

    slot: int
    timesteps: int               # cumulative timesteps consumed by the stream
    readout: np.ndarray          # cumulative readout at ``timesteps``
    chunk_spikes: int            # output spikes this chunk (all layers)
    cycles: int                  # cumulative async-pipeline makespan cycles
    energy_uj: float             # cumulative calibrated energy
    spikes: int = 0              # cumulative output spikes (all layers)
    # Multi-core plans only (engine compiled with a CoreSchedule): the
    # stream's cumulative per-core cycle attribution and the current load
    # imbalance (max/mean busy) of its placement.  None/0 on single core.
    per_core_cycles: Optional[np.ndarray] = None
    load_imbalance: float = 0.0
    # This chunk's (t, n_layers) input-spike counts — populated only when
    # the manager was built with ``collect_chunk_counts=True`` (used by
    # ``launch/serve.py --trace-out`` to re-price finished streams with
    # ``collect_timeline=True`` for the per-stream pipeline timeline).
    input_counts: Optional[np.ndarray] = None


class StreamSessionManager:
    """Multiplex up to ``capacity`` live event streams onto one engine.

    ``step(chunks)`` takes ``{slot: events}`` with ``events`` of shape
    ``(t, H, W, C)``, ``t <= chunk_T`` (a shorter *final* chunk is
    zero-padded and the readout is snapshotted at the true last timestep),
    and returns ``{slot: SlotUpdate}``.

    The bit-exactness contract is *enforced*, not advisory: every open slot
    must deliver a chunk on every tick (a slot idling through a tick would
    silently advance its resident Vmem through zero-input timesteps — leak
    decay the whole-stream run never saw), and a slot that delivered a
    short chunk has ended its stream and must be ``close()``d before the
    next tick.  Violations raise immediately instead of corrupting state.
    """

    def __init__(self, engine: SNNEngine, capacity: int = 4,
                 chunk_T: int = 2, *, metrics=None, tracer=None,
                 collect_chunk_counts: bool = False, device=None):
        assert capacity >= 1 and chunk_T >= 1
        self.engine = engine
        self.capacity = capacity
        self.chunk_T = chunk_T
        self.device = device
        spec = engine.spec
        self._frame_shape = tuple(spec.input_hw) + (spec.in_channels,)
        # Telemetry (repro.obs).  ``None`` binds the process-wide defaults
        # (disabled unless ``obs.enable_metrics()``/``enable_tracing()`` is
        # called — enabling is retroactive since the objects are shared);
        # ``False`` pins telemetry hard-off for this session regardless of
        # the globals.  Every record site is guarded by one truthiness
        # check, so the disabled path stays within the <1% dispatch budget
        # gated by the ``telemetry_overhead`` benchmark.
        self._metrics = (obs_metrics.default_registry() if metrics is None
                         else (metrics or obs_metrics.MetricsRegistry(False)))
        self._tracer = (obs_trace.default_tracer() if tracer is None
                        else (tracer or obs_trace.Tracer(enabled=False)))
        self._collect_chunk_counts = bool(collect_chunk_counts)
        self._m = None  # lazily bound metric handles (first enabled tick)
        # Position-weighted input-plane size per timestep — the sparsity
        # denominator, identical to the cost model's definition.
        self._positions_per_t = float(
            sum(s.fan_in * s.out_positions for s in spec.layer_shapes()))
        self.state = init_state(engine, capacity)
        if device is not None:
            # Replica device placement: commit the session's resident state
            # to one host device so a fleet of sessions ticks on distinct
            # devices (the jitted step follows its committed operands).
            self.state = jax.device_put(self.state, device)
        self.active = [False] * capacity
        self.ended = [False] * capacity   # delivered a short (final) chunk
        # Per-slot cumulative accounting (host side, O(capacity)).
        self.slot_timesteps = np.zeros(capacity, np.int64)
        self.slot_spikes = np.zeros(capacity, np.int64)
        self.slot_cycles = np.zeros(capacity, np.int64)
        self.slot_energy_uj = np.zeros(capacity, np.float64)
        # Resumable async-handshake clocks per slot: pricing chunk by chunk
        # with carried state gives the same cumulative makespan as pricing
        # the whole stream at once (chunking-invariant cycle accounting).
        # Multi-core plans keep one clock set per core (a list per slot)
        # plus cumulative per-core routing cycles (additive across chunks).
        self._pipe_state = [None] * capacity
        self._schedule = engine.schedule
        n_cores = engine.schedule.n_cores if engine.schedule else 1
        self._slot_route_cycles = np.zeros((capacity, n_cores), np.int64)
        self.slot_core_cycles = np.zeros((capacity, n_cores), np.int64)
        self.slot_imbalance = np.ones(capacity, np.float64)
        self.ticks = 0
        # One jitted step for the session's lifetime: fixed (chunk_T,
        # capacity, H, W, C) event shape, fixed state shapes.
        self._step = jax.jit(
            lambda st, ev: run_chunk(engine, st, ev, collect_counts=True,
                                     collect_readouts=True)
        )
        self._reset = jax.jit(reset_slot)

    # -- lifecycle ---------------------------------------------------------
    def open(self) -> Optional[int]:
        """Allocate a slot for a new stream; None if the session is full.

        The slot's device state needs no reset here: ``init_state`` zeroed
        every slot at construction and ``close()`` re-zeroes on retirement,
        so an inactive slot is already all-zero — admission is free.
        """
        for i in range(self.capacity):
            if not self.active[i]:
                self.active[i] = True
                self.ended[i] = False
                self.slot_timesteps[i] = 0
                self.slot_spikes[i] = 0
                self.slot_cycles[i] = 0
                self.slot_energy_uj[i] = 0.0
                self._pipe_state[i] = None
                self._slot_route_cycles[i] = 0
                self.slot_core_cycles[i] = 0
                self.slot_imbalance[i] = 1.0
                return i
        return None

    def close(self, slot: int) -> None:
        """Retire a stream: zero the slot so it is inert until reused."""
        assert self.active[slot], f"slot {slot} is not active"
        self.active[slot] = False
        self.ended[slot] = False
        self.state = self._reset(self.state, jnp.int32(slot))

    @property
    def occupancy(self) -> int:
        return sum(self.active)

    # -- telemetry ---------------------------------------------------------
    def _metric_handles(self):
        """Bind (and cache) the session's metric objects on first use."""
        if self._m is None:
            reg = self._metrics
            self._m = {
                "ticks": reg.counter(
                    "spidr_session_ticks_total", "Session step() calls"),
                "timesteps": reg.counter(
                    "spidr_stream_timesteps_total",
                    "Timesteps consumed across all streams"),
                "in_spikes": reg.counter(
                    "spidr_stream_input_spikes_total",
                    "Layer-input spikes across all streams"),
                "out_spikes": reg.counter(
                    "spidr_stream_output_spikes_total",
                    "Layer-output spikes across all streams"),
                "cycles": reg.counter(
                    "spidr_stream_cycles_total",
                    "Async-pipeline makespan cycle increments"),
                "energy": reg.counter(
                    "spidr_stream_energy_uj_total",
                    "Calibrated energy across all streams (uJ)"),
                "occupancy": reg.gauge(
                    "spidr_session_occupancy",
                    "Open slots at the last tick"),
                "sparsity": reg.histogram(
                    "spidr_chunk_sparsity",
                    "Per-slot per-chunk input sparsity",
                    edges=obs_metrics.FRACTION_BUCKETS),
                "tile_frac": reg.histogram(
                    "spidr_chunk_nonzero_tile_frac",
                    "Per-slot per-chunk nonzero event-tile fraction "
                    "(zero-skip opportunity)",
                    edges=obs_metrics.FRACTION_BUCKETS),
                "slot_cycles": [reg.gauge(
                    "spidr_slot_cycles",
                    "Cumulative makespan cycles of the stream in each slot",
                    labels={"slot": i}) for i in range(self.capacity)],
                "slot_energy": [reg.gauge(
                    "spidr_slot_energy_uj",
                    "Cumulative energy of the stream in each slot (uJ)",
                    labels={"slot": i}) for i in range(self.capacity)],
                "slot_imbalance": [reg.gauge(
                    "spidr_slot_load_imbalance",
                    "Per-slot multi-core load imbalance (max/mean busy)",
                    labels={"slot": i}) for i in range(self.capacity)],
            }
        return self._m

    def _nonzero_tile_frac(self, chunk: np.ndarray) -> float:
        """Fraction of ``block_k``-wide event tiles holding any spike.

        The engine's zero-skip kernels drop all-zero GEMM tiles; this is
        the host-side view of how much of the input plane they get to
        skip, tiled along the flattened (H*W*C) axis with the engine's
        ``block_k``.
        """
        t = chunk.shape[0]
        flat = chunk.reshape(t, -1)
        bk = int(self.engine.cfg.block[2])
        k = flat.shape[1]
        n_tiles = -(-k // bk)
        pad = n_tiles * bk - k
        if pad:
            flat = np.pad(flat, ((0, 0), (0, pad)))
        nz = (flat.reshape(t, n_tiles, bk) != 0).any(axis=2)
        return float(nz.sum() / nz.size)

    # -- the batched tick --------------------------------------------------
    def step(self, chunks: Dict[int, np.ndarray]) -> Dict[int, SlotUpdate]:
        """Advance every slot by ``chunk_T`` timesteps in one fused call."""
        missing = [i for i in range(self.capacity)
                   if self.active[i] and i not in chunks]
        assert not missing, (
            f"open slots {missing} delivered no chunk this tick; an idle "
            "open slot would advance its Vmem through zero-input timesteps "
            "and diverge from the whole-stream result — deliver every tick "
            "or close() the slot")
        ev = np.zeros((self.chunk_T, self.capacity) + self._frame_shape,
                      np.float32)
        valid = {}
        for slot, chunk in chunks.items():
            assert self.active[slot], f"slot {slot} is not active"
            assert not self.ended[slot], (
                f"slot {slot} already delivered a short (final) chunk; "
                "close() it before the next tick")
            chunk = np.asarray(chunk)
            assert chunk.shape[1:] == self._frame_shape, chunk.shape
            t = chunk.shape[0]
            assert 1 <= t <= self.chunk_T, (t, self.chunk_T)
            if t < self.chunk_T:
                self.ended[slot] = True
            ev[:t, slot] = chunk
            valid[slot] = t

        # Telemetry pre-capture: cumulative counters only ever accumulate
        # *deltas*, so totals are chunking-invariant (tested).
        telemetry = bool(self._metrics)
        if telemetry:
            prev_cycles = self.slot_cycles.copy()
            prev_energy = self.slot_energy_uj.copy()

        if self._tracer:
            with self._tracer.span("run_chunk", cat="session",
                                   tick=self.ticks, slots=len(valid)):
                self.state, out = self._step(self.state, jnp.asarray(ev))
                # Sync inside the span so it measures the device step, not
                # just async dispatch (we host-transfer right below anyway).
                out = jax.block_until_ready(out)
        else:
            self.state, out = self._step(self.state, jnp.asarray(ev))
        self.ticks += 1

        readouts = np.asarray(out.readouts)          # (chunk_T, capacity, ...)
        slot_out = np.asarray(out.slot_spike_counts)  # (chunk_T, L, capacity)
        slot_in = np.asarray(out.slot_input_counts)

        updates = {}
        for slot, t in valid.items():
            # Price only this stream's own spikes: its per-slot input counts
            # over the chunk's valid timesteps, through the async-pipeline +
            # calibrated-energy models.  Idle slots are never charged.
            counts = slot_in[:t, :, slot]
            per_core_cycles, imbalance = None, 0.0
            if self._schedule is not None:
                cost = estimate_multicore_cost(
                    self.engine.spec, self._schedule, counts,
                    pipeline_states=self._pipe_state[slot])
                self._pipe_state[slot] = cost.pipeline_states
                # Per-core pipeline clocks resume across chunks; routing
                # cycles are additive — cumulative attribution stays
                # chunking-invariant, like the single-core path.
                self._slot_route_cycles[slot] += cost.routing_cycles
                makespans = np.array(
                    [pc.makespan_cycles for pc in cost.per_core], np.int64)
                per_core_cycles = makespans + self._slot_route_cycles[slot]
                self.slot_core_cycles[slot] = per_core_cycles
                self.slot_cycles[slot] = int(per_core_cycles.max())
                self.slot_imbalance[slot] = imbalance = cost.load_imbalance
                self.slot_energy_uj[slot] += float(cost.energy_uj)
            else:
                cost = estimate_cost(self.engine.spec, self.engine.cfg.qspec,
                                     counts,
                                     pipeline_state=self._pipe_state[slot])
                self._pipe_state[slot] = cost.pipeline_state
                # Resumed clocks make the makespan cumulative since the
                # stream began — identical to a whole-stream estimate, any
                # chunking.
                self.slot_cycles[slot] = int(cost.makespan_cycles)
                self.slot_energy_uj[slot] += float(cost.energy_uj)
            chunk_spikes = int(slot_out[:t, :, slot].sum())
            self.slot_timesteps[slot] += t
            self.slot_spikes[slot] += chunk_spikes
            updates[slot] = SlotUpdate(
                slot=slot,
                timesteps=int(self.slot_timesteps[slot]),
                # Snapshot at the stream's true last timestep: zero-padded
                # tail steps never leak into a short final chunk's readout.
                readout=readouts[t - 1, slot],
                chunk_spikes=chunk_spikes,
                cycles=int(self.slot_cycles[slot]),
                energy_uj=float(self.slot_energy_uj[slot]),
                spikes=int(self.slot_spikes[slot]),
                per_core_cycles=per_core_cycles,
                load_imbalance=imbalance,
                input_counts=(counts.copy()
                              if self._collect_chunk_counts else None),
            )
        if telemetry:
            self._record_tick(chunks, valid, slot_in, updates,
                              prev_cycles, prev_energy)
        return updates

    def _record_tick(self, chunks, valid, slot_in, updates,
                     prev_cycles, prev_energy) -> None:
        """Fold one tick into the metrics registry (enabled path only)."""
        m = self._metric_handles()
        m["ticks"].inc()
        m["occupancy"].set(self.occupancy)
        for slot, t in valid.items():
            up = updates[slot]
            in_spikes = float(slot_in[:t, :, slot].sum())
            m["timesteps"].inc(t)
            m["in_spikes"].inc(in_spikes)
            m["out_spikes"].inc(up.chunk_spikes)
            # Cumulative makespan is monotone per stream; exporting the
            # per-tick *increment* keeps the counter chunking-invariant.
            m["cycles"].inc(float(self.slot_cycles[slot] - prev_cycles[slot]))
            m["energy"].inc(
                float(self.slot_energy_uj[slot] - prev_energy[slot]))
            density = in_spikes / (self._positions_per_t * t)
            m["sparsity"].observe(float(np.clip(1.0 - density, 0.0, 1.0)))
            m["tile_frac"].observe(
                self._nonzero_tile_frac(np.asarray(chunks[slot])))
            m["slot_cycles"][slot].set(float(self.slot_cycles[slot]))
            m["slot_energy"][slot].set(float(self.slot_energy_uj[slot]))
            if self._schedule is not None:
                m["slot_imbalance"][slot].set(float(self.slot_imbalance[slot]))

    # -- durability: serializable session state ----------------------------
    @property
    def n_cores(self) -> int:
        return self._schedule.n_cores if self._schedule is not None else 1

    def _pipe_dicts(self, slot: int) -> list:
        """Per-core clock dicts for one slot, ``None`` normalized to zeros.

        A never-stepped slot's ``None`` clock is bit-equivalent to
        :meth:`PipelineState.zero` (``simulate_pipeline`` zero-initializes
        when no state is given), so the serialized structure is identical
        for every slot — a requirement for restoring through the fixed-
        structure checkpoint format.
        """
        ps = self._pipe_state[slot]
        if ps is None:
            per_core = [PipelineState.zero() for _ in range(self.n_cores)]
        elif isinstance(ps, list):
            per_core = ps
        else:
            per_core = [ps]
        assert len(per_core) == self.n_cores, (len(per_core), self.n_cores)
        return [p.to_dict() for p in per_core]

    def state_dict(self) -> dict:
        """The session's full durable state as a deterministic pure-numpy
        tree: every live slot's integer :class:`EngineState` leaves, the
        session table (open/ended flags, cumulative per-slot accounting),
        and the resumable async-handshake clocks.

        Every array is a fresh host copy — nothing aliases the manager's
        live buffers, so ``state_dict`` at tick k is immutable evidence of
        tick k no matter how the session advances afterwards.  The schema
        is pinned by ``tests/test_streaming_durability.py``; round-tripping
        through :meth:`load_state_dict` is bit-exact (tested for any
        snapshot boundary, chunking and slot open/close interleaving).
        """
        st = self.state
        return {
            "schema": np.int64(SESSION_SCHEMA_VERSION),
            "engine_state": {
                "vmem": [None if v is None else np.asarray(v).copy()
                         for v in st.vmem],
                "readout_acc": np.asarray(st.readout_acc).copy(),
                "out_counts": np.asarray(st.out_counts).copy(),
                "in_counts": np.asarray(st.in_counts).copy(),
            },
            "table": {
                "active": np.asarray(self.active, np.bool_),
                "ended": np.asarray(self.ended, np.bool_),
                "timesteps": self.slot_timesteps.copy(),
                "spikes": self.slot_spikes.copy(),
                "cycles": self.slot_cycles.copy(),
                "energy_uj": self.slot_energy_uj.copy(),
                "route_cycles": self._slot_route_cycles.copy(),
                "core_cycles": self.slot_core_cycles.copy(),
                "imbalance": self.slot_imbalance.copy(),
                "ticks": np.int64(self.ticks),
            },
            "clocks": [self._pipe_dicts(s) for s in range(self.capacity)],
        }

    def load_state_dict(self, d: dict) -> None:
        """Restore the session to a :meth:`state_dict` snapshot, bit-exactly.

        The manager must have been constructed over the same engine
        geometry (capacity, chunk size, core count, layer shapes); a
        mismatched snapshot raises ``ValueError`` before any state is
        touched.  After the load, every subsequent ``step`` emits spikes,
        readouts and cumulative cycle/energy attribution identical to a
        session that was never interrupted.
        """
        schema = int(d["schema"])
        if schema > SESSION_SCHEMA_VERSION:
            raise ValueError(
                f"session snapshot schema {schema} is newer than this "
                f"build's {SESSION_SCHEMA_VERSION} — upgrade the code or "
                "re-snapshot")
        es, table, clocks = d["engine_state"], d["table"], d["clocks"]
        if len(table["active"]) != self.capacity:
            raise ValueError(
                f"snapshot holds {len(table['active'])} slots but this "
                f"session has capacity {self.capacity} — restore onto a "
                "session opened with the snapshot's geometry")
        if len(clocks) != self.capacity \
                or any(len(c) != self.n_cores for c in clocks):
            raise ValueError(
                f"snapshot clock layout {len(clocks)}x"
                f"{len(clocks[0]) if clocks else 0} does not match this "
                f"session's {self.capacity}x{self.n_cores} (capacity x "
                "cores) — was it taken on a different compiled plan?")
        vmem = []
        for cur, new in zip(self.state.vmem, es["vmem"]):
            if (cur is None) != (new is None) or (
                    cur is not None and cur.shape != np.shape(new)):
                raise ValueError(
                    "snapshot Vmem shapes do not match this engine's "
                    "layers — restore onto the same network/spec")
            vmem.append(None if new is None
                        else jnp.asarray(new, jnp.int32))
        self.state = dataclasses.replace(
            self.state,
            vmem=tuple(vmem),
            readout_acc=jnp.asarray(es["readout_acc"],
                                    self.state.readout_acc.dtype),
            out_counts=jnp.asarray(es["out_counts"], jnp.int32),
            in_counts=jnp.asarray(es["in_counts"], jnp.int32),
        )
        self.active = [bool(a) for a in np.asarray(table["active"])]
        self.ended = [bool(e) for e in np.asarray(table["ended"])]
        self.slot_timesteps = np.asarray(table["timesteps"], np.int64).copy()
        self.slot_spikes = np.asarray(table["spikes"], np.int64).copy()
        self.slot_cycles = np.asarray(table["cycles"], np.int64).copy()
        self.slot_energy_uj = np.asarray(table["energy_uj"],
                                         np.float64).copy()
        self._slot_route_cycles = np.asarray(table["route_cycles"],
                                             np.int64).copy()
        self.slot_core_cycles = np.asarray(table["core_cycles"],
                                           np.int64).copy()
        self.slot_imbalance = np.asarray(table["imbalance"],
                                         np.float64).copy()
        self.ticks = int(table["ticks"])
        pipe = []
        for per_core in clocks:
            states = [PipelineState.from_dict(p) for p in per_core]
            pipe.append(states if self._schedule is not None else states[0])
        self._pipe_state = pipe

    # -- live migration: one slot's durable state --------------------------
    def export_slot(self, slot: int) -> dict:
        """One live stream's complete durable state as a pure-numpy tree.

        The per-slot slice of :meth:`state_dict` — resident Vmem, readout
        accumulator, spike counters, the session table's cumulative
        accounting, and the resumable handshake clocks.  Fresh host copies,
        nothing aliases live buffers.  Because batch slots never interact
        inside the engine, ``export_slot`` on manager A followed by
        :meth:`import_slot` on manager B (same engine geometry) continues
        the stream bit-exactly: identical spikes, readouts and cumulative
        cycle/energy attribution to a never-migrated run.
        """
        if not self.active[slot]:
            raise ValueError(
                f"slot {slot} is not active — only a live stream's state "
                "can be exported for migration")
        st = self.state
        return {
            "schema": np.int64(SESSION_SCHEMA_VERSION),
            "vmem": [None if v is None else np.asarray(v[slot]).copy()
                     for v in st.vmem],
            "readout_acc": np.asarray(st.readout_acc[slot]).copy(),
            "out_counts": np.asarray(st.out_counts[:, slot]).copy(),
            "in_counts": np.asarray(st.in_counts[:, slot]).copy(),
            "table": {
                "ended": bool(self.ended[slot]),
                "timesteps": int(self.slot_timesteps[slot]),
                "spikes": int(self.slot_spikes[slot]),
                "cycles": int(self.slot_cycles[slot]),
                "energy_uj": float(self.slot_energy_uj[slot]),
                "route_cycles": self._slot_route_cycles[slot].copy(),
                "core_cycles": self.slot_core_cycles[slot].copy(),
                "imbalance": float(self.slot_imbalance[slot]),
            },
            "clocks": self._pipe_dicts(slot),
        }

    def import_slot(self, payload: dict, slot: Optional[int] = None) -> int:
        """Install an :meth:`export_slot` payload into a free slot.

        ``slot`` picks the destination explicitly (must be free); the
        default takes the first free slot, like :meth:`open`.  The payload
        must come from a session over the same engine geometry (layer
        shapes, core count) — mismatches raise ``ValueError`` before any
        state is touched.  Returns the destination slot, now active and
        continuing the stream bit-exactly.
        """
        schema = int(payload["schema"])
        if schema > SESSION_SCHEMA_VERSION:
            raise ValueError(
                f"slot payload schema {schema} is newer than this build's "
                f"{SESSION_SCHEMA_VERSION} — upgrade the code or re-export")
        if slot is None:
            slot = next((i for i in range(self.capacity)
                         if not self.active[i]), None)
            if slot is None:
                raise ValueError(
                    "no free slot to import into — close a stream or "
                    "migrate to a session with free capacity")
        elif self.active[slot]:
            raise ValueError(
                f"slot {slot} already holds a live stream — import into a "
                "free slot")
        if len(payload["clocks"]) != self.n_cores:
            raise ValueError(
                f"slot payload carries {len(payload['clocks'])} core "
                f"clock(s) but this session runs {self.n_cores} — was it "
                "exported from a different compiled plan?")
        st = self.state
        for cur, new in zip(st.vmem, payload["vmem"]):
            if (cur is None) != (new is None) or (
                    cur is not None and cur.shape[1:] != np.shape(new)):
                raise ValueError(
                    "slot payload Vmem shapes do not match this engine's "
                    "layers — migrate between replicas of the same "
                    "network/spec")
        vmem = tuple(
            cur if cur is None
            else cur.at[slot].set(jnp.asarray(new, jnp.int32))
            for cur, new in zip(st.vmem, payload["vmem"]))
        self.state = dataclasses.replace(
            st,
            vmem=vmem,
            readout_acc=st.readout_acc.at[slot].set(
                jnp.asarray(payload["readout_acc"],
                            st.readout_acc.dtype)),
            out_counts=st.out_counts.at[:, slot].set(
                jnp.asarray(payload["out_counts"], jnp.int32)),
            in_counts=st.in_counts.at[:, slot].set(
                jnp.asarray(payload["in_counts"], jnp.int32)),
        )
        table = payload["table"]
        self.active[slot] = True
        self.ended[slot] = bool(table["ended"])
        self.slot_timesteps[slot] = int(table["timesteps"])
        self.slot_spikes[slot] = int(table["spikes"])
        self.slot_cycles[slot] = int(table["cycles"])
        self.slot_energy_uj[slot] = float(table["energy_uj"])
        self._slot_route_cycles[slot] = np.asarray(table["route_cycles"],
                                                   np.int64)
        self.slot_core_cycles[slot] = np.asarray(table["core_cycles"],
                                                 np.int64)
        self.slot_imbalance[slot] = float(table["imbalance"])
        states = [PipelineState.from_dict(p) for p in payload["clocks"]]
        self._pipe_state[slot] = (states if self._schedule is not None
                                  else states[0])
        return slot
