"""Batched multi-timestep SNN inference engine (the fused-timestep spine).

``inference`` builds an integer (bit-exact) engine from a trained/initialized
network and runs whole ``(T, B, H, W, C)`` event streams through it with a
``lax.scan`` over time; ``cost`` threads the run's spike statistics through
the calibrated pipeline/energy models.
"""
from .cost import EngineCost, estimate_cost
from .inference import (
    EngineConfig,
    EngineOutput,
    SNNEngine,
    build_engine,
    run_engine,
    run_reference,
)

__all__ = [
    "EngineConfig",
    "EngineOutput",
    "SNNEngine",
    "build_engine",
    "run_engine",
    "run_reference",
    "EngineCost",
    "estimate_cost",
]
