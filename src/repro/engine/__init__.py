"""Batched multi-timestep SNN inference engine (the fused-timestep spine).

``inference`` builds an integer (bit-exact) engine from a trained/initialized
network and runs event streams through it — either whole ``(T, B, H, W, C)``
tensors (``run_engine``) or chunk by chunk with persistent neuron state
(``init_state`` / ``run_chunk``, bit-identical under any chunking);
``streaming`` multiplexes many live streams onto one fixed-shape batched
chunk step with per-slot cost accounting; ``cost`` threads a run's spike
statistics through the calibrated pipeline/energy models.
"""
from .cost import (
    EngineCost,
    MulticoreCost,
    estimate_cost,
    estimate_multicore_cost,
)
from .inference import (
    ChunkOutput,
    EngineConfig,
    EngineOutput,
    EngineState,
    SNNEngine,
    build_engine,
    compile_engine,
    init_state,
    reset_slot,
    run_chunk,
    run_engine,
    run_reference,
)
from .streaming import SlotUpdate, StreamSessionManager

__all__ = [
    "ChunkOutput",
    "EngineConfig",
    "EngineOutput",
    "EngineState",
    "SNNEngine",
    "build_engine",
    "compile_engine",
    "init_state",
    "reset_slot",
    "run_chunk",
    "run_engine",
    "run_reference",
    "EngineCost",
    "MulticoreCost",
    "estimate_cost",
    "estimate_multicore_cost",
    "SlotUpdate",
    "StreamSessionManager",
]
