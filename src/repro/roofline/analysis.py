"""Roofline analysis from compiled HLO (no hardware required).

Three terms per (arch x shape x mesh) cell, all PER-CHIP:

  compute term    = dot_FLOPs_local / peak_FLOPs            [s]
  memory term     = HBM_bytes_local / HBM_bw                [s]
  collective term = wire_bytes_local / (links * link_bw)    [s]

Sources:
  * ``compiled.as_text()`` — post-SPMD HLO with LOCAL (per-device) shapes.
    We parse every ``dot`` op (operand shapes resolved through a per-
    computation symbol table) and every collective, and multiply ops inside
    while-loop bodies by the loop trip count, which XLA records as
    ``backend_config={"known_trip_count":{"n":N}}``.  This fixes the
    known undercount of ``cost_analysis()`` (scan bodies counted once —
    verified empirically: a 10-iteration scan reports 10x fewer FLOPs).
  * Memory term: analytic traffic model (params + activation boundaries +
    KV/state cache; see ``_memory_bytes``) — cost_analysis byte counts
    share the while-loop undercount and on CPU include host copies, so the
    analytic model is the per-chip HBM estimate we trust; both are
    reported.

Hardware constants (TPU v5e-class, per the assignment):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI.

Wire-byte convention: all-reduce counts 2x payload (reduce-scatter +
all-gather of a ring), others 1x; payload is the op's local result bytes.
"""
from __future__ import annotations

import re
from typing import Optional

import numpy as np

__all__ = [
    "parse_hlo",
    "analyze_compiled",
    "HW_PEAK",
    "LayerBound",
    "PerfModel",
]

HW_PEAK = {
    "flops_bf16": 197e12,   # per chip
    "ops_int8": 394e12,     # int8 MXU ops/s (2x the bf16 MAC rate)
    "hbm_gbps": 819e9,      # bytes/s
    "ici_link_gbps": 50e9,  # bytes/s per link
    "ici_links": 1,         # conservative single-link budget per chip
    "hbm_gib": 16.0,        # v5e HBM capacity
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{")
_OP_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_CALLED_RE = re.compile(r"(?:body|calls|to_apply|condition)=%?([\w\.\-]+)")

COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    """Bytes of a result type, handling tuples."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def parse_hlo(text: str) -> dict:
    """Parse compiled HLO text -> per-chip dot FLOPs + collective bytes."""
    # ---- split into computations ----------------------------------------
    comps: dict = {}
    cur = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = hdr.group(2)
            comps[cur] = {"ops": [], "symtab": {}}
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        _, name, rtype, opkind, rest = m.groups()
        comps[cur]["symtab"][name] = rtype
        comps[cur]["ops"].append((name, rtype, opkind, rest, line))

    # ---- build caller->callee multipliers --------------------------------
    mult = {c: 1.0 for c in comps}
    # Repeated relaxation handles nesting (child mult = parent mult * trip).
    edges = []  # (parent, child, factor)
    for cname, comp in comps.items():
        for name, rtype, opkind, rest, line in comp["ops"]:
            factor = 1.0
            if opkind == "while":
                t = _TRIP_RE.search(line)
                if t:
                    factor = float(t.group(1))
            for callee in _CALLED_RE.findall(line):
                if callee in comps:
                    edges.append((cname, callee, factor if opkind == "while" else 1.0))
    for _ in range(12):  # fixpoint over nesting depth
        changed = False
        for parent, child, factor in edges:
            want = mult[parent] * factor
            if want > mult[child]:
                mult[child] = want
                changed = True
        if not changed:
            break

    # ---- dots + collectives ----------------------------------------------
    flops = 0.0
    coll_bytes = 0.0
    coll_by_kind: dict = {}
    dots = []
    colls = []
    for cname, comp in comps.items():
        m_ = mult[cname]
        symtab = comp["symtab"]
        for name, rtype, opkind, rest, line in comp["ops"]:
            if opkind == "dot":
                out_dims = _shape_dims(rtype) or []
                out_n = float(np.prod(out_dims)) if out_dims else 1.0
                # contraction size from lhs operand shape.  Depending on the
                # XLA version the operand is printed inline-typed
                # ("dot(f32[64,512]{1,0} %param, ...)") or bare ("dot(%param,
                # ...)"); read the inline type first, else the symbol table.
                cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                lhs_dims = None
                if _SHAPE_RE.match(rest):
                    lhs_dims = _shape_dims(rest.split(" ")[0])
                else:
                    lhs_m = re.match(r"%?([\w\.\-]+)", rest)
                    if lhs_m and lhs_m.group(1) in symtab:
                        lhs_dims = _shape_dims(symtab[lhs_m.group(1)])
                csize = 1.0
                if cdims_m and lhs_dims:
                    for ci in cdims_m.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            csize *= lhs_dims[int(ci)]
                f = 2.0 * out_n * csize * m_
                flops += f
                dots.append({"comp": cname, "out": rtype, "flops": f})
            elif opkind in COLLECTIVES:
                b = _shape_bytes(rtype) * COLLECTIVES[opkind] * m_
                coll_bytes += b
                coll_by_kind[opkind] = coll_by_kind.get(opkind, 0.0) + b
                meta = re.search(r'op_name="([^"]*)"', line)
                colls.append({
                    "comp": cname, "kind": opkind, "out": rtype.split("{")[0],
                    "bytes": b, "mult": m_,
                    "op_name": meta.group(1) if meta else "",
                })
    dots.sort(key=lambda d: -d["flops"])
    colls.sort(key=lambda c: -c["bytes"])
    return {
        "dot_flops": flops,
        "collective_bytes": coll_bytes,
        "collective_by_kind": coll_by_kind,
        "top_dots": dots[:8],
        "top_collectives": colls[:10],
        "all_collectives": colls,
        "n_computations": len(comps),
    }


# ---------------------------------------------------------------------------
# Analytic per-chip HBM traffic model (see module docstring).
# ---------------------------------------------------------------------------
def _memory_bytes(cfg, shape, n_chips: int, model_axis: int) -> float:
    n_params = cfg.param_count()
    d = cfg.d_model
    b_local = max(shape.global_batch // max(n_chips // model_axis, 1), 1)
    if shape.kind == "train":
        # fp32 params sharded over all chips (FSDP x TP): fwd read + bwd read
        # + grad write + AdamW (read p,mu,nu + write p,mu,nu) = 9 passes.
        param_traffic = 9.0 * 4.0 * n_params / n_chips
        # activation boundaries: save + reload per layer (remat recomputes
        # interior): 2 passes of (B_local, S, D) bf16 per layer.
        act = 4.0 * b_local * shape.seq_len * d * 2.0 * cfg.n_layers
        return param_traffic + act
    if shape.kind == "prefill":
        param_traffic = 4.0 * n_params / n_chips
        act = 2.0 * b_local * shape.seq_len * d * 2.0 * cfg.n_layers
        # KV cache write
        kv = 2.0 * b_local * shape.seq_len * cfg.n_kv_heads * cfg.head_dim_ * 2.0 \
            * cfg.n_layers / model_axis
        return param_traffic + act + kv
    # decode: full param read + full cache read per token.
    param_traffic = 4.0 * n_params / n_chips
    if cfg.family == "ssm":
        state = cfg.n_layers * b_local * (d // 64) * 64 * 64 * 4.0 * 2.0
        return param_traffic + state
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // (cfg.attn_period or 6)
        n_mamba = cfg.n_layers - n_attn
        state = n_mamba * b_local * (cfg.d_inner // 64) * cfg.ssm_state * 64 * 4.0 * 2.0
        kv = 2.0 * b_local * shape.seq_len * cfg.n_kv_heads * cfg.head_dim_ * 2.0 \
            * n_attn / model_axis
        return param_traffic + state + kv
    kv = 2.0 * b_local * shape.seq_len * cfg.n_kv_heads * cfg.head_dim_ * 2.0 \
        * cfg.n_layers / model_axis
    return param_traffic + kv


def analyze_compiled(compiled, cfg, shape, mesh_devices: int, model_axis: int,
                     bf16_wire: bool = False) -> dict:
    parsed = parse_hlo(compiled.as_text())
    peak = HW_PEAK
    compute_s = parsed["dot_flops"] / peak["flops_bf16"]
    mem_bytes = _memory_bytes(cfg, shape, mesh_devices, model_axis)
    memory_s = mem_bytes / peak["hbm_gbps"]
    coll_bytes = parsed["collective_bytes"]
    if bf16_wire:
        # TPU-dtype normalization: the CPU backend's FloatNormalization pass
        # runs BEFORE SPMD partitioning and upcasts every bf16 dot to f32,
        # so dot-adjacent collectives (param all-gathers, partial-sum and
        # gradient reductions) appear as 4-byte words in the compiled HLO
        # even when params/activations are bf16.  On the TPU target those
        # dots are native bf16 and the same collectives move 2-byte words
        # (MaxText-observed behavior).  Halve dot-attributed collectives.
        dot_bytes = sum(
            c["bytes"] for c in parsed["all_collectives"]
            if "dot_general" in c["op_name"] and "f32" in c["out"]
        )
        coll_bytes = coll_bytes - dot_bytes / 2.0
    coll_s = coll_bytes / (peak["ici_links"] * peak["ici_link_gbps"])

    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)

    # MODEL_FLOPS: 6*N*D with N = (active) params, D = tokens processed.
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    model_flops_global = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    model_flops_local = model_flops_global / mesh_devices
    hlo = parsed["dot_flops"]
    useful = model_flops_local / hlo if hlo else 0.0

    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bottleneck": bottleneck,
        "dot_flops_local": parsed["dot_flops"],
        "collective_bytes_local": parsed["collective_bytes"],
        "collective_by_kind": {k: round(v) for k, v in parsed["collective_by_kind"].items()},
        "memory_bytes_local": mem_bytes,
        "model_flops_local": model_flops_local,
        "useful_flops_ratio": useful,
        "step_time_lower_bound_s": max(terms.values()),
        "roofline_fraction": useful * (compute_s / max(terms.values())) if hlo else 0.0,
        "top_dots": parsed["top_dots"][:5],
        "top_collectives": parsed["top_collectives"][:8],
    }


# ---------------------------------------------------------------------------
# SNN kernel performance model: analytic wall-time bounds for the fused
# Vmem-stationary T_blk kernel (kernels.fused_lif_gemm_int_tblk).
#
# A thin, explicit wrapper in the style of DaCe's RooflineModel: peaks in,
# (bytes-moved, MACs-at-sparsity) per layer, bound = max(compute, memory).
# The bound is an *ideal-hardware* floor — interpret-mode CPU runs sit far
# above it — so the CI perf gate (tools/check_bench.py) tracks the RATIO
# measured_wall / bound against the committed baseline's ratio: the bound
# normalizes shape/sparsity/tiling differences out of the wall clock, and
# a regression in the ratio means the implementation got slower relative
# to what the dataflow says it should cost.
# ---------------------------------------------------------------------------
import dataclasses as _dataclasses


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


@_dataclasses.dataclass(frozen=True)
class LayerBound:
    """Roofline bound for one weight layer over a whole event chunk."""

    rows: int                # GEMM M (batch x output positions)
    fan_in: int              # GEMM K
    channels: int            # GEMM N
    timesteps: int
    t_block: int
    macs: float              # MACs actually issued (after tile skipping)
    bytes_moved: float       # HBM bytes under the T_blk tiling
    compute_s: float
    memory_s: float

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s)

    @property
    def bottleneck(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"


class PerfModel:
    """Analytic roofline for the fused SNN hot path.

    ``peaks`` defaults to :data:`HW_PEAK`; pass overrides to model other
    parts (``{"ops_int8": ..., "hbm_gbps": ...}``).  All methods are pure
    and deterministic — the same (shape, precision, tiling, sparsity)
    always prices to the same bound, which is what lets benchmarks commit
    measured/bound ratios as a regression baseline.
    """

    def __init__(self, peaks: Optional[dict] = None):
        self.peaks = dict(HW_PEAK)
        if peaks:
            self.peaks.update(peaks)

    def layer_bound(
        self,
        rows: int,
        fan_in: int,
        channels: int,
        *,
        timesteps: int,
        t_block: int = 1,
        nonzero_tile_frac: float = 1.0,
        block: tuple = (128, 128, 128),
    ) -> LayerBound:
        """Bound one layer's chunk under the T_blk tiling.

        ``nonzero_tile_frac`` is the fraction of (bm x bk) spike tiles
        that carry at least one spike (measure it with
        ``kernels.spike_tile_bitmap``); it scales the MAC term — the
        block-sparsity lever — while the byte terms keep the dense spike
        stream (the bitmap is read either way; weight traffic is decided
        by tiling, not sparsity).

        Byte model of ``fused_lif_gemm_int_tblk`` per chunk:
          * weights: the (K_p x N_p) int8 matrix streams once per m-tile
            per kernel call — ``gm * K_p * N_p * ceil(T / T_blk)``; this
            is the term the Vmem-stationary tiling divides by T_blk;
          * spikes: each (T_blk, bm, bk) int8 stack is read once per
            n-tile — ``T * R_p * K_p * gn``;
          * Vmem carry: the (bm, bn) int32 tile reads once per (i, j)
            per call;
          * outputs: the (T, M, N) int32 trajectory + spike stacks write
            once each.
        """
        bm, bn, bk = block
        t_block = max(1, min(t_block, timesteps))
        r_p, k_p, n_p = _ceil_to(rows, bm), _ceil_to(fan_in, bk), \
            _ceil_to(channels, bn)
        gm, gn = r_p // bm, n_p // bn
        n_calls = -(-timesteps // t_block)

        w_bytes = float(gm * k_p * n_p) * n_calls
        s_bytes = float(timesteps * r_p * k_p) * gn
        v_bytes = 4.0 * r_p * n_p * n_calls
        out_bytes = 2.0 * 4.0 * timesteps * r_p * n_p
        bytes_moved = w_bytes + s_bytes + v_bytes + out_bytes

        macs = float(rows) * fan_in * channels * timesteps \
            * max(0.0, min(1.0, nonzero_tile_frac))
        compute_s = 2.0 * macs / self.peaks["ops_int8"]
        memory_s = bytes_moved / self.peaks["hbm_gbps"]
        return LayerBound(
            rows=rows, fan_in=fan_in, channels=channels,
            timesteps=timesteps, t_block=t_block,
            macs=macs, bytes_moved=bytes_moved,
            compute_s=compute_s, memory_s=memory_s,
        )

    def network_bound(
        self,
        spec,
        *,
        batch: int = 1,
        timesteps: Optional[int] = None,
        t_block: int = 1,
        block: tuple = (128, 128, 128),
        nonzero_tile_fracs=None,
        layer_kcfgs=None,
    ) -> dict:
        """Aggregate per-layer bounds over an ``SNNSpec``.

        ``nonzero_tile_fracs`` is a per-weight-layer list (default: dense,
        1.0); ``layer_kcfgs`` optionally overrides (bm, bn, bk, t_blk) per
        weight layer — pass ``EngineLayer.kcfg`` values to price an
        autotuned engine.  Returns per-layer :class:`LayerBound` rows plus
        total bytes/MACs and the summed wall-time bound in seconds and
        microseconds.
        """
        shapes = spec.layer_shapes()
        timesteps = spec.timesteps if timesteps is None else timesteps
        if nonzero_tile_fracs is None:
            nonzero_tile_fracs = [1.0] * len(shapes)
        if layer_kcfgs is None:
            layer_kcfgs = [None] * len(shapes)
        layers = []
        for sh, frac, kcfg in zip(shapes, nonzero_tile_fracs, layer_kcfgs):
            rows = batch * sh.out_positions if sh.kind == "conv" else batch
            blk, tb = block, t_block
            if kcfg is not None:
                blk, tb = tuple(kcfg[:3]), kcfg[3]
            layers.append(self.layer_bound(
                rows, sh.fan_in, sh.out_channels,
                timesteps=timesteps, t_block=tb,
                nonzero_tile_frac=frac, block=blk,
            ))
        bound_s = sum(lb.bound_s for lb in layers)
        return {
            "layers": layers,
            "bytes_moved": sum(lb.bytes_moved for lb in layers),
            "macs": sum(lb.macs for lb in layers),
            "compute_s": sum(lb.compute_s for lb in layers),
            "memory_s": sum(lb.memory_s for lb in layers),
            "bound_s": bound_s,
            "bound_us": bound_s * 1e6,
        }
