"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the JSONs."""
import glob
import json
import os

HERE = os.path.dirname(__file__)

ARCH_ORDER = [
    "qwen1.5-0.5b", "starcoder2-3b", "qwen3-14b", "stablelm-3b", "rwkv6-7b",
    "granite-moe-3b-a800m", "moonshot-v1-16b-a3b", "musicgen-large",
    "chameleon-34b", "zamba2-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(cell):
    path = os.path.join(HERE, "dryrun", cell + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def fmt_cell(d, opt=None):
    if d is None:
        return None
    if d["status"] == "skipped":
        return {"skip": True, "reason": d.get("reason", "")}
    if d["status"] != "ok":
        return {"error": d.get("error", "")[:80]}
    r = d["roofline"]
    m = d["memory_analysis"]
    mem = (m["argument_size_in_bytes"] + m["temp_size_in_bytes"] +
           m.get("output_size_in_bytes", 0)) / 2**30
    out = {
        "mem_gib": mem,
        "compute_s": r["compute_s"],
        "memory_s": r["memory_s"],
        "collective_s": r["collective_s"],
        "bottleneck": r["bottleneck"],
        "useful": r["useful_flops_ratio"],
        "frac": r["roofline_fraction"],
        "flops": r["dot_flops_local"],
        "coll_gb": r["collective_bytes_local"] / 1e9,
        "variant": d.get("resolved_variant", "base"),
    }
    return out


def dryrun_table(pod):
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = load(f"{arch}__{shape}__{pod}")
            c = fmt_cell(d)
            if c is None:
                continue
            if c.get("skip"):
                rows.append(f"| {arch} | {shape} | SKIP (sub-quadratic rule) | | | |")
                continue
            rows.append(
                f"| {arch} | {shape} | ok | {c['mem_gib']:.1f} | "
                f"{c['flops']/1e12:.2f} | {c['coll_gb']:.1f} |"
            )
    hdr = ("| arch | shape | status | bytes/device (GiB) | HLO TFLOPs/chip | "
           "collective GB/chip |\n|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table(opt=False):
    rows = []
    suffix = "__auto" if opt else ""
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = load(f"{arch}__{shape}__pod1{suffix}")
            c = fmt_cell(d)
            if c is None or c.get("skip") or c.get("error"):
                continue
            rows.append(
                f"| {arch} | {shape} | {c['compute_s']:.4f} | {c['memory_s']:.4f} | "
                f"{c['collective_s']:.4f} | {c['bottleneck']} | {c['useful']:.2f} | "
                f"{c['frac']:.3f} |" + (f" {c['variant']} |" if opt else "")
            )
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck | "
           "MODEL/HLO flops | roofline frac |" + (" policy |" if opt else ""))
    sep = "|---" * (9 if opt else 8) + "|"
    return hdr + "\n" + sep + "\n" + "\n".join(rows)


def before_after():
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            b = fmt_cell(load(f"{arch}__{shape}__pod1"))
            o = fmt_cell(load(f"{arch}__{shape}__pod1__auto"))
            if not b or not o or b.get("skip") or o.get("skip"):
                continue
            if b.get("error") or o.get("error"):
                continue
            sb = max(b["compute_s"], b["memory_s"], b["collective_s"])
            so = max(o["compute_s"], o["memory_s"], o["collective_s"])
            rows.append(
                f"| {arch} | {shape} | {b['frac']:.3f} | {o['frac']:.3f} | "
                f"{sb/so:.1f}x | {b['mem_gib']:.0f} -> {o['mem_gib']:.0f} | "
                f"{o['variant']} |"
            )
    hdr = ("| arch | shape | baseline frac | optimized frac | step-time gain | "
           "GiB/device | policy |\n|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("### pod1 (16x16)\n")
        print(dryrun_table("pod1"))
        print("\n### pod2 (2x16x16)\n")
        print(dryrun_table("pod2"))
    if which in ("roofline", "all"):
        print("\n### baseline roofline (pod1)\n")
        print(roofline_table(False))
    if which in ("opt", "all"):
        print("\n### optimized (auto policy)\n")
        print(roofline_table(True))
        print("\n### before/after\n")
        print(before_after())
