"""End-to-end driver: train the paper's gesture SNN for a few hundred steps.

  PYTHONPATH=src python examples/train_gesture_snn.py [--steps 200] [--bits 4]

Surrogate-gradient BPTT + QAT at the chosen SpiDR precision, with
checkpointing + fault-tolerant loop, then evaluation and the deployment
summary (energy per inference from the calibrated model).  This is the
"train a model for a few hundred steps" deliverable (the paper's kind is
an inference accelerator for small SNNs, so the end-to-end driver trains
the paper's own workload, not a 100M LM).
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.core.energy import chunk_energy_total_nj
from repro.core.modes import CoreConfig, map_layer
from repro.core.network import gesture_net
from repro.core.quant import QuantSpec
from repro.snn.data import make_gesture_batch
from repro.snn.train import TrainConfig, evaluate, init_train_state, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--bits", type=int, default=4, choices=(4, 6, 8))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--timesteps", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/spidr_gesture_ckpt")
    args = ap.parse_args()

    spec = gesture_net()
    cfg = TrainConfig(weight_bits=args.bits, lr=2e-3)
    state = init_train_state(jax.random.PRNGKey(0), spec, cfg)
    ckpt = Checkpointer(args.ckpt)
    key = jax.random.PRNGKey(1)

    print(f"training gesture SNN (Table II) @ {args.bits}/{2*args.bits-1}-bit "
          f"for {args.steps} steps")
    t0 = time.time()
    for step in range(args.steps):
        key, k = jax.random.split(key)
        ev, lbl = make_gesture_batch(k, batch=args.batch,
                                     timesteps=args.timesteps, hw=(64, 64))
        state, m = train_step(state, (ev, lbl), spec, cfg)
        if step % 20 == 0:
            print(f"  step {step:4d} loss {float(m['loss']):.4f} "
                  f"acc {float(m['accuracy']):.2f}")
        if (step + 1) % 100 == 0:
            ckpt.save_async(step + 1, state.params)
    ckpt.wait()
    dt = time.time() - t0

    # Eval on held-out synthetic batches.
    accs = []
    for i in range(4):
        key, k = jax.random.split(key)
        ev, lbl = make_gesture_batch(k, batch=16, timesteps=args.timesteps,
                                     hw=(64, 64))
        accs.append(evaluate(state.params, [(ev, lbl)], spec, cfg))
    print(f"\ntrained {args.steps} steps in {dt:.1f}s; eval acc "
          f"{np.mean(accs):.2f} (chance 1/11 = 0.09)")

    # Deployment summary from the calibrated accelerator model.
    core = CoreConfig(QuantSpec(args.bits))
    passes = sum(map_layer(s, core).total_passes for s in spec.layer_shapes())
    e_uj = passes * spec.timesteps * chunk_energy_total_nj(0.95) / 1e3
    print(f"deployed on SpiDR: {passes} macro passes/timestep, "
          f"~{e_uj:.0f} uJ per inference @95% sparsity (Table I model)")


if __name__ == "__main__":
    main()
