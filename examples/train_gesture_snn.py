"""End-to-end driver: train the paper's gesture SNN for a few hundred steps.

  PYTHONPATH=src python examples/train_gesture_snn.py [--steps 200] [--bits 4]

Surrogate-gradient BPTT + QAT at the chosen SpiDR precision, with
checkpointing + fault-tolerant loop, then evaluation and deployment
through the unified `spidr` facade (export -> compile -> verify -> cost).
This is the "train a model for a few hundred steps" deliverable (the
paper's kind is an inference accelerator for small SNNs, so the
end-to-end driver trains the paper's own workload, not a 100M LM).

SPIDR_SMOKE=1 shrinks steps/frames/timesteps for CI.
"""
import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.core.network import gesture_net
from repro.core.quant import QuantSpec
from repro.snn.data import make_gesture_batch
from repro.snn.train import TrainConfig, evaluate, init_train_state, train_step

SMOKE = os.environ.get("SPIDR_SMOKE") == "1"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5 if SMOKE else 200)
    ap.add_argument("--bits", type=int, default=4, choices=(4, 6, 8))
    ap.add_argument("--batch", type=int, default=2 if SMOKE else 8)
    ap.add_argument("--timesteps", type=int, default=2 if SMOKE else 8)
    ap.add_argument("--hw", type=int, default=16 if SMOKE else 64)
    ap.add_argument("--ckpt", default="/tmp/spidr_gesture_ckpt")
    args = ap.parse_args()

    spec = gesture_net()
    hw = (args.hw, args.hw)
    run_spec = dataclasses.replace(spec, input_hw=hw,
                                   timesteps=args.timesteps)
    cfg = TrainConfig(weight_bits=args.bits, lr=2e-3)
    state = init_train_state(jax.random.PRNGKey(0), run_spec, cfg)
    ckpt = Checkpointer(args.ckpt)
    key = jax.random.PRNGKey(1)

    print(f"training gesture SNN (Table II) @ {args.bits}/{2*args.bits-1}-bit "
          f"for {args.steps} steps")
    t0 = time.time()
    for step in range(args.steps):
        key, k = jax.random.split(key)
        ev, lbl = make_gesture_batch(k, batch=args.batch,
                                     timesteps=args.timesteps, hw=hw)
        state, m = train_step(state, (ev, lbl), run_spec, cfg)
        if step % 20 == 0:
            print(f"  step {step:4d} loss {float(m['loss']):.4f} "
                  f"acc {float(m['accuracy']):.2f}")
        if (step + 1) % 100 == 0:
            ckpt.save_async(step + 1, state.params)
    ckpt.wait()
    dt = time.time() - t0

    # Eval on held-out synthetic batches.
    accs = []
    for i in range(2 if SMOKE else 4):
        key, k = jax.random.split(key)
        ev, lbl = make_gesture_batch(k, batch=16, timesteps=args.timesteps,
                                     hw=hw)
        accs.append(evaluate(state.params, [(ev, lbl)], run_spec, cfg))
    print(f"\ntrained {args.steps} steps in {dt:.1f}s; eval acc "
          f"{np.mean(accs):.2f} (chance 1/11 = 0.09)")

    # Deploy through the unified facade: export the QAT integers, compile
    # onto a target, prove the round trip, and price an inference on the
    # calibrated chip models.
    from repro import spidr
    from repro.snn.export import export_network

    exported = export_network(state.params, run_spec, QuantSpec(args.bits))
    compiled = spidr.compile(exported, state.params,
                             spidr.DeployTarget(weight_bits=args.bits),
                             spec=run_spec)
    key, k = jax.random.split(key)
    ev, _ = make_gesture_batch(k, batch=2, timesteps=args.timesteps, hw=hw)
    report = compiled.verify(ev)
    cost = compiled.cost(compiled.run(ev))
    print(f"deployed on SpiDR via {compiled!r}:\n"
          f"  train->deploy round trip exact={report.exact}; "
          f"{cost.makespan_cycles} cycles, {cost.energy_uj:.1f} uJ per "
          f"inference ({cost.mean_sparsity:.1%} measured sparsity)")


if __name__ == "__main__":
    main()
