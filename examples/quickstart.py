"""Quickstart: the SpiDR stack in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end to end on synthetic DVS events:
  1. build the gesture SNN (Table II) at 4/7-bit precision,
  2. run spiking inference (float QAT path AND bit-exact integer path),
  3. map every layer onto the accelerator (modes, Sec II-E),
  4. report throughput / energy from the calibrated Table I model,
  5. run the same accumulation through the Pallas spike-GEMM kernel,
  6. deploy through the unified `spidr` facade — one DeployTarget declares
     precision/backend/cores, `spidr.compile` returns a CompiledSNN that
     runs whole event streams, prices them on the chip cost model, and
     proves its own round-trip parity.

SPIDR_SMOKE=1 shrinks frames/timesteps for CI.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import HW, gops, power_mw, tops_per_watt
from repro.core.modes import CoreConfig, map_layer
from repro.core.network import gesture_net, init_params, run_snn
from repro.core.quant import QuantSpec
from repro.kernels.ref import spike_gemm_ref
from repro.kernels.spike_gemm import spike_gemm
from repro.snn.data import make_gesture_batch

SMOKE = os.environ.get("SPIDR_SMOKE") == "1"

spec4 = QuantSpec(4)
print(f"precision: {spec4} (B_vmem = 2*B_w - 1 = {spec4.vmem_bits})")

# 1-2. network + inference ---------------------------------------------------
net = gesture_net()
params = init_params(jax.random.PRNGKey(0), net)
events, labels = make_gesture_batch(jax.random.PRNGKey(1),
                                    batch=2 if SMOKE else 4,
                                    timesteps=4 if SMOKE else 10,
                                    hw=(32, 32) if SMOKE else (64, 64))
sparsity = float(jnp.mean(events == 0))
run_net = net if not SMOKE else dataclasses.replace(
    net, input_hw=(32, 32), timesteps=4)
logits, _ = run_snn(params, events, run_net, spec4)
print(f"input sparsity {sparsity:.1%}; rate-coded logits shape {logits.shape}")

# 3. accelerator mapping ------------------------------------------------------
core = CoreConfig(spec4)
print("\nlayer mapping (Sec II-E):")
for i, shape in enumerate(net.layer_shapes()):
    m = map_layer(shape, core)
    print(f"  L{i}: {shape.kind} fan_in={shape.fan_in:4d} -> mode {m.mode}, "
          f"{m.parallel_channels} parallel ch, {m.total_passes} passes")

# 4. throughput / energy (Table I model) --------------------------------------
hw = HW(50e6, 0.9)
print(f"\n@50MHz/0.9V: {power_mw(hw):.1f} mW, "
      f"{gops(sparsity, 4):.1f} GOPS, {tops_per_watt(sparsity, 4, hw):.2f} TOPS/W "
      f"at measured sparsity {sparsity:.2%}")

# 5. Pallas kernel (TPU adaptation, interpret mode on CPU) --------------------
rng = np.random.default_rng(0)
spikes = (rng.random((128, 256)) < 1 - sparsity).astype(np.int8)
w = rng.integers(spec4.w_min, spec4.w_max + 1, (256, 48)).astype(np.int8)
out = spike_gemm(jnp.array(spikes), jnp.array(w), interpret=True)
ok = bool(jnp.all(out == spike_gemm_ref(jnp.array(spikes), jnp.array(w))))
print(f"\nPallas spike_gemm == oracle: {ok}")

# 6. the unified deployment facade --------------------------------------------
# One DeployTarget declares the whole deployment (precision pair, backend,
# cores, chunking); spidr.compile returns a CompiledSNN owning the fused
# multi-timestep engine.  .run / .cost / .verify cover the lifecycle —
# .open_stream / .save / spidr.load are the rest (docs/api.md).
from repro import spidr
from repro.configs import spidr_gesture

small = spidr_gesture.reduced(hw=(16, 16) if SMOKE else (32, 32),
                              timesteps=2 if SMOKE else 4)
sparams = init_params(jax.random.PRNGKey(0), small)
target = spidr.DeployTarget(weight_bits=4, backend="fused", interpret=True)
compiled = spidr.compile(small, sparams, target)
print(f"\n{compiled!r}")

sev, _ = make_gesture_batch(jax.random.PRNGKey(2), batch=2,
                            timesteps=small.timesteps, hw=small.input_hw)
result = compiled.run(sev)
# Per-stream chip cost: the engine records whole-batch spike counts, so
# normalize by the batch size before pricing.
cost = compiled.cost(
    input_counts=np.asarray(result.input_counts) / sev.shape[1])
print(f"fused engine: rate readout {np.asarray(result.readout).tolist()}")
print(f"chip estimate/stream: {cost.latency_ms:.2f} ms, {cost.energy_uj:.1f} uJ "
      f"at {cost.mean_sparsity:.1%} sparsity (async speedup "
      f"{cost.async_speedup:.2f}x)")
report = compiled.verify(sev)
print(f"round-trip parity proof: exact={report.exact}")
