"""Optical-flow SNN inference with bit-exact integer deployment + energy.

  PYTHONPATH=src python examples/optical_flow_inference.py

Runs the paper's DSEC-flow network (Table II) on synthetic translating-
texture event streams, compares the float (training) path against the
bit-exact integer (deployment) path through the unified `spidr` facade —
including a compiled 4-core plan — and reports AEE + the accelerator
cycle/energy estimate under the paper's Mode-2 mapping.

SPIDR_SMOKE=1 shrinks the crop/timesteps for CI.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import HW, cycles_per_chunk, gops, power_mw
from repro.core.modes import CoreConfig, map_layer
from repro.core.network import init_params, optical_flow_net, run_snn
from repro.core.pipeline import simulate_pipeline
from repro.core.quant import QuantSpec
from repro.snn.data import make_flow_batch

SMOKE = os.environ.get("SPIDR_SMOKE") == "1"
HW_, SPEC = HW(50e6, 0.9), QuantSpec(4)

net = optical_flow_net()
params = init_params(jax.random.PRNGKey(0), net)

# Small crop for a quick CPU demo (full 288x384 works, just slower).
crop, T = ((24, 32), 2) if SMOKE else ((72, 96), 5)
events, flow_gt = make_flow_batch(jax.random.PRNGKey(1), batch=1, timesteps=T,
                                  hw=crop)
sparsity = float(jnp.mean(events == 0))

small = dataclasses.replace(net, input_hw=crop, timesteps=T)
pred, counts = run_snn(params, events, small, SPEC, record_spikes=True)
aee = float(jnp.mean(jnp.linalg.norm(pred - flow_gt, axis=-1)))
print(f"input sparsity {sparsity:.1%}; untrained AEE {aee:.2f} px/step "
      f"(train with snn.train to reduce)")

# Bit-exact integer deployment through the unified facade: the same spec +
# params, quantized into the integer engine, on 1 core and on a compiled
# 4-core plan (identical spikes — the compiler is bit-exact).
from repro import spidr

compiled = spidr.compile(small, params, spidr.DeployTarget(weight_bits=4))
out = compiled.run(events)
cost = compiled.cost(out)
print(f"\ndeployed (integer engine): Vmem readout {np.asarray(out.readout).shape}, "
      f"{cost.makespan_cycles} cycles, {cost.energy_uj:.1f} uJ "
      f"({cost.mean_sparsity:.1%} measured sparsity)")

multi = spidr.compile(small, params,
                      spidr.DeployTarget(weight_bits=4, n_cores=4))
mout = multi.run(events)
mcost = multi.cost(mout)
exact = bool((np.asarray(out.readout) == np.asarray(mout.readout)).all())
print(f"4-core compiled plan: bit-exact={exact}, makespan "
      f"{mcost.makespan_cycles} cycles, load imbalance "
      f"{mcost.load_imbalance:.2f}x, routing {int(mcost.routing_cycles.sum())} "
      "cycles")

# Accelerator view: Mode mapping + timestep pipeline simulation.
core = CoreConfig(SPEC)
print("\nlayer mapping:")
total_passes = 0
for i, shape in enumerate(small.layer_shapes()):
    m = map_layer(shape, core)
    total_passes += m.total_passes
    print(f"  L{i}: fan_in={shape.fan_in:4d} mode={m.mode} passes={m.total_passes}")

rng = np.random.default_rng(0)
per_macro_cycles = rng.integers(
    int(2 * 2048 * (1 - sparsity) * 0.5), int(2 * 2048 * (1 - sparsity) * 1.5) + 2,
    (small.timesteps, 9),
)
res = simulate_pipeline(per_macro_cycles)
print(f"\ntimestep pipeline (Fig 13): {res.makespan} cycles for "
      f"{small.timesteps} timesteps; {res.speedup_vs_sync:.2f}x vs rigid sync")
t_chunk = cycles_per_chunk(sparsity) / HW_.freq_hz
print(f"per-chunk latency {t_chunk*1e6:.1f} us; core: {power_mw(HW_):.1f} mW, "
      f"{gops(sparsity, 4):.1f} GOPS @ measured sparsity")
