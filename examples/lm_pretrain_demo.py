"""LM-framework demo: train a reduced assigned arch with the full substrate.

  PYTHONPATH=src python examples/lm_pretrain_demo.py [--arch qwen1.5-0.5b]

Exercises the large-scale stack end-to-end on host devices: config system,
synthetic data pipeline, AdamW, checkpoint/restart (kill it mid-run and
re-run — it resumes), watchdog + straggler stats.  The same step function
is what the multi-pod dry-run lowers at (16,16)/(2,16,16).

SPIDR_SMOKE=1 shrinks the step budget for CI.  (This is the LM substrate
demo — the SNN deployment facade examples are quickstart.py,
optical_flow_inference.py and train_gesture_snn.py.)
"""
import argparse
import os

from repro.launch import train as T

SMOKE = os.environ.get("SPIDR_SMOKE") == "1"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=12 if SMOKE else 60)
    args = ap.parse_args()

    ns = argparse.Namespace(
        arch=args.arch, steps=args.steps, batch=8, seq=64, lr=1e-3, seed=0,
        reduced=True, weight_bits=4, ckpt_dir=f"/tmp/repro_lm_{args.arch}",
        ckpt_every=25, watchdog_s=600.0,
    )
    history = T.train_lm(ns)
    print(f"loss: {history[0]:.3f} -> {history[-1]:.3f} over {len(history)} steps")
    assert history[-1] < history[0], "loss should decrease on structured data"


if __name__ == "__main__":
    main()
