#!/usr/bin/env python
"""Zero-downtime upgrade drill: SIGKILL a streaming server, restore, compare.

For every configuration in the matrix (gesture + optical-flow, 1 and 4
cores, fused-Pallas and jnp backends) the drill:

  1. serves a deterministic multi-stream workload uninterrupted in-process
     and records every stream's final readout / cumulative cycles / energy
     (the reference);
  2. launches a child process that serves the same workload with
     per-tick snapshots and SIGKILLs *itself mid-chunk* at a randomized
     tick — after the session stepped, before any bookkeeping, the worst
     possible instant;
  3. launches a second child that restores from the latest on-disk
     snapshot (``repro.serving.StreamWorker.restore``) and serves to
     completion;
  4. asserts the restored results are byte-identical to the reference for
     every stream — zero sessions lost state.

Usage:
  python tools/upgrade_drill.py --smoke --out drill_report.json
  python tools/upgrade_drill.py --seed 7          # full geometry

Exit status is non-zero if any configuration mismatches; the JSON report
records per-config kill ticks and per-stream verdicts.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402


def matrix():
    return [{"task": task, "n_cores": cores, "backend": backend}
            for task in ("gesture", "optical-flow")
            for cores in (1, 4)
            for backend in ("fused", "jnp")]


def geometry(smoke: bool) -> dict:
    if smoke:
        return {"hw": [16, 16], "timesteps": 6, "capacity": 2,
                "chunk_T": 2, "n_streams": 4}
    return {"hw": [32, 32], "timesteps": 10, "capacity": 3,
            "chunk_T": 2, "n_streams": 6}


def build(cfg: dict):
    """Deterministically compile the config's deployment (any process)."""
    import jax

    from repro import spidr
    from repro.configs import spidr_gesture, spidr_optflow
    from repro.core.network import init_params

    mod = spidr_gesture if cfg["task"] == "gesture" else spidr_optflow
    spec = mod.reduced(hw=tuple(cfg["hw"]), timesteps=cfg["timesteps"])
    params = init_params(jax.random.PRNGKey(0), spec)
    target = spidr.DeployTarget(
        weight_bits=4, n_cores=cfg["n_cores"], backend=cfg["backend"],
        chunk_T=cfg["chunk_T"], stream_capacity=cfg["capacity"])
    return spidr.compile(spec, params, target), spec


def make_requests(cfg: dict, seed: int) -> dict:
    """The drill workload: streams of *differing* lengths (slot churn),
    regenerated identically in every process from the seed alone."""
    from repro.serving import StreamRequest

    spec_c = 2
    h, w = cfg["hw"]
    t_max = cfg["timesteps"]
    rng = np.random.default_rng(seed)
    reqs = {}
    for rid in range(cfg["n_streams"]):
        t = int(rng.integers(max(2, t_max // 2), t_max + 1))
        ev = (rng.random((t, h, w, spec_c)) < 0.1).astype(np.float32)
        reqs[rid] = StreamRequest(rid=rid, events=ev)
    return reqs


def results_of(server) -> dict:
    return {str(r.rid): {
        "readout": np.asarray(r.readout).tolist(),
        "cycles": int(r.cycles),
        "energy_uj": float(r.energy_uj),
        "timesteps": int(r.cursor),
    } for r in server.done}


def serve_reference(cfg: dict, seed: int):
    """Uninterrupted run; returns (results, n_ticks)."""
    from repro.serving import StreamWorker

    compiled, _ = build(cfg)
    server = StreamWorker(compiled, capacity=cfg["capacity"],
                                chunk_T=cfg["chunk_T"])
    for rid, req in sorted(make_requests(cfg, seed).items()):
        server.submit(req)
    while server.step():
        pass
    return results_of(server), server.ticks


# ---------------------------------------------------------------------------
# Child modes (run in their own process).
# ---------------------------------------------------------------------------
def child_serve(cfg: dict, seed: int, snap_dir: str, die_at: int) -> None:
    """Serve with per-tick snapshots; SIGKILL ourselves mid-tick at
    ``die_at`` — after the session stepped, before bookkeeping/snapshot."""
    from repro import obs
    from repro.serving import StreamWorker

    # Trace the whole doomed run: compile/autotune spans plus every
    # serve.tick/run_chunk up to the fatal tick.  The trace is exported
    # from the mid-tick hook — synchronously, before the SIGKILL lands —
    # so the parent can embed the kill-tick span timeline in its report.
    obs.enable_tracing()
    tracer = obs.default_tracer()
    compiled, _ = build(cfg)
    server = StreamWorker(compiled, capacity=cfg["capacity"],
                                chunk_T=cfg["chunk_T"],
                                snapshot_dir=snap_dir, snapshot_every=1)

    def kill_mid_tick(tick: int) -> None:
        if tick == die_at:
            os.makedirs(snap_dir, exist_ok=True)
            tracer.export(os.path.join(snap_dir, "kill_trace.json"))
            os.kill(os.getpid(), signal.SIGKILL)

    server.mid_tick_hook = kill_mid_tick
    for rid, req in sorted(make_requests(cfg, seed).items()):
        server.submit(req)
    while server.step():
        pass
    raise SystemExit(3)  # reached only if the kill tick never arrived


def child_restore(cfg: dict, seed: int, snap_dir: str, out: str) -> None:
    """Fresh process: restore the latest snapshot, serve to completion."""
    from repro.serving import StreamWorker

    server = StreamWorker.restore(snap_dir,
                                        make_requests(cfg, seed))
    resumed_at = server.ticks
    while server.step():
        pass
    with open(out, "w") as f:
        json.dump({"results": results_of(server),
                   "resumed_at_tick": resumed_at,
                   "final_tick": server.ticks}, f)


# ---------------------------------------------------------------------------
# The drill.
# ---------------------------------------------------------------------------
def spawn(extra: list) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run([sys.executable, os.path.abspath(__file__)] + extra,
                          env=env, capture_output=True, text=True,
                          timeout=1200)


def drill_config(cfg: dict, seed: int) -> dict:
    t0 = time.monotonic()
    reference, n_ticks = serve_reference(cfg, seed)
    # Randomized kill tick: >= 2 so at least one snapshot exists on disk.
    kill_rng = np.random.default_rng(seed * 1000 + cfg["n_cores"])
    die_at = int(kill_rng.integers(2, max(n_ticks, 2) + 1))
    record = dict(cfg, ticks=n_ticks, die_at_tick=die_at,
                  streams=len(reference))

    with tempfile.TemporaryDirectory(prefix="spidr_drill_") as tmp:
        snap = os.path.join(tmp, "snap")
        cfg_json = json.dumps(cfg)
        a = spawn(["--child", "serve", "--cfg", cfg_json, "--dir", snap,
                   "--seed", str(seed), "--die-at", str(die_at)])
        record["serve_returncode"] = a.returncode
        if a.returncode != -signal.SIGKILL:
            record.update(ok=False, error=(
                f"serve child exited {a.returncode}, expected SIGKILL "
                f"({-signal.SIGKILL}): {a.stderr[-2000:]}"))
            return record
        trace_path = os.path.join(snap, "kill_trace.json")
        if os.path.exists(trace_path):
            with open(trace_path) as f:
                spans = [e for e in json.load(f)["traceEvents"]
                         if e.get("ph") == "X"]
            # The span timeline leading into the kill: the last few
            # completed spans (the fatal tick's run_chunk is the newest —
            # its serve.tick parent never closed, the process died inside).
            record["kill_trace"] = {
                "total_spans": len(spans),
                "final_spans": [
                    {"name": e["name"], "cat": e.get("cat"),
                     "ts_us": e["ts"], "dur_us": e["dur"],
                     "args": e.get("args", {})}
                    for e in spans[-8:]],
            }
        out = os.path.join(tmp, "results.json")
        b = spawn(["--child", "restore", "--cfg", cfg_json, "--dir", snap,
                   "--seed", str(seed), "--out", out])
        if b.returncode != 0:
            record.update(ok=False, error=(
                f"restore child exited {b.returncode}: {b.stderr[-2000:]}"))
            return record
        with open(out) as f:
            restored = json.load(f)

    record["resumed_at_tick"] = restored["resumed_at_tick"]
    mismatches = []
    for rid, want in reference.items():
        got = restored["results"].get(rid)
        if got != want:
            mismatches.append({"rid": rid, "want": want, "got": got})
    lost = sorted(set(reference) - set(restored["results"]))
    record.update(ok=not mismatches and not lost, mismatches=mismatches,
                  lost_streams=lost,
                  wall_s=round(time.monotonic() - t0, 2))
    return record


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry (CI): same 8-config matrix")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write a JSON report here")
    ap.add_argument("--child", choices=["serve", "restore"], default=None)
    ap.add_argument("--cfg", default=None)
    ap.add_argument("--dir", default=None)
    ap.add_argument("--die-at", type=int, default=None, dest="die_at")
    args = ap.parse_args()

    if args.child is not None:
        cfg = json.loads(args.cfg)
        if args.child == "serve":
            child_serve(cfg, args.seed, args.dir, args.die_at)
        else:
            child_restore(cfg, args.seed, args.dir, args.out)
        return 0

    geo = geometry(args.smoke)
    records = []
    for cfg in matrix():
        cfg = dict(cfg, **geo)
        print(f"[drill] {cfg['task']} x {cfg['n_cores']} core(s) x "
              f"{cfg['backend']} ...", flush=True)
        rec = drill_config(cfg, args.seed)
        verdict = "OK" if rec["ok"] else f"FAIL ({rec.get('error', 'diff')})"
        print(f"[drill]   killed at tick {rec.get('die_at_tick')}/"
              f"{rec.get('ticks')}, resumed at "
              f"{rec.get('resumed_at_tick', '?')}: {verdict}", flush=True)
        records.append(rec)

    ok = all(r["ok"] for r in records)
    report = {"seed": args.seed, "smoke": bool(args.smoke),
              "ok": ok, "configs": records}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"[drill] report -> {args.out}")
    print(f"[drill] {'ALL OK' if ok else 'FAILURES'}: "
          f"{sum(r['ok'] for r in records)}/{len(records)} configs "
          "restored with zero lost state")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
