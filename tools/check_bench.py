#!/usr/bin/env python3
"""Benchmark regression gate: diff a fresh BENCH_compiler.json vs baseline.

``benchmarks/run.py`` writes machine-readable records (cycles, energy,
exactness, deployed accuracy/AEE) for every tracked ablation; this tool
compares a freshly generated file against the committed
``benchmarks/baseline.json`` and fails loudly when a record regresses:

  * a record present in the baseline disappears;
  * an exactness flag that was True turns False (bit-exactness is a hard
    contract, no tolerance);
  * ``cycles`` / ``energy_uj`` grow beyond ``--tol`` (relative);
  * the deployed quality metric regresses beyond ``--tol-metric``
    (absolute) — ``accuracy`` falling or ``aee`` rising;
  * the measured/roofline ratio ``wall_us / bound_us`` grows beyond
    ``--tol-roofline`` (relative) — only for records whose BASELINE
    carries both fields.  Raw ``wall_us`` stays ungated (CI runners are
    not comparable machines); the analytic bound from
    ``roofline.analysis.PerfModel`` normalizes shape/sparsity/tiling out
    of the wall clock, so the ratio moves only when the implementation
    gets slower relative to what its dataflow should cost.  The default
    tolerance is deliberately loose (3.0 = 4x the committed ratio):
    interpret-mode wall clock jitters across runners, and the gate exists
    to catch order-of-magnitude schedule regressions (a dropped
    block-skip, a retraced jit, a T_blk tiling that stopped engaging).

Improvements (fewer cycles, less energy, better metric) always pass, with
a note suggesting a baseline refresh so the gate tightens over time.

Usage:
    PYTHONPATH=src python benchmarks/run.py --smoke --out BENCH_compiler.json
    python tools/check_bench.py BENCH_compiler.json

Refreshing the baseline after an intentional change:
    PYTHONPATH=src python benchmarks/run.py --smoke --out benchmarks/baseline.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_BASELINE = REPO / "benchmarks" / "baseline.json"

REFRESH_HINT = (
    "If this change is intentional, refresh the committed baseline with:\n"
    "    PYTHONPATH=src python benchmarks/run.py --smoke "
    "--out benchmarks/baseline.json\n"
    "and commit the result."
)

# Numeric fields under the relative ``--tol`` gate; True = lower is better.
COST_FIELDS = {"cycles": True, "energy_uj": True}
# Quality metrics under the absolute ``--tol-metric`` gate.
HIGHER_BETTER_METRICS = {"accuracy"}
LOWER_BETTER_METRICS = {"aee"}


def _load(path: pathlib.Path) -> dict:
    payload = json.loads(path.read_text())
    records = payload.get("results", [])
    if not records:
        raise SystemExit(f"ERROR: {path} contains no benchmark records")
    return {r["name"]: r for r in records}


def _check_record(base: dict, fresh: dict, tol: float, tol_metric: float,
                  tol_roofline: float = 3.0):
    """Yield failure strings for one record pair."""
    name = base["name"]
    # Roofline-ratio gate: applies only when the BASELINE committed both a
    # measured wall time and a predicted bound (records without bound_us
    # keep the long-standing contract that wall_us alone is ignored).
    if "wall_us" in base and "bound_us" in base:
        if "wall_us" in fresh and "bound_us" in fresh:
            base_ratio = base["wall_us"] / max(base["bound_us"], 1e-12)
            got_ratio = fresh["wall_us"] / max(fresh["bound_us"], 1e-12)
            limit = base_ratio * (1.0 + tol_roofline)
            if got_ratio > limit:
                yield (
                    f"{name}: wall/roofline ratio regressed "
                    f"{base_ratio:.1f} -> {got_ratio:.1f} "
                    f"(+{(got_ratio / max(base_ratio, 1e-12) - 1) * 100:.0f}%, "
                    f"tolerance {tol_roofline * 100:.0f}%) — measured "
                    f"{fresh['wall_us']:.0f}us vs predicted bound "
                    f"{fresh['bound_us']:.1f}us"
                )
        # A missing wall_us/bound_us falls through to the field-disappeared
        # check below, which reports it.
    for field, value in base.items():
        if field not in fresh:
            yield f"{name}: field '{field}' disappeared from the fresh run"
            continue
        got = fresh[field]
        if field in COST_FIELDS:
            limit = value * (1.0 + tol)
            if got > limit:
                yield (
                    f"{name}: {field} regressed {value} -> {got} "
                    f"(+{(got / max(value, 1e-12) - 1) * 100:.1f}%, "
                    f"tolerance {tol * 100:.0f}%)"
                )
        elif isinstance(value, bool):
            if value and not got:
                yield f"{name}: {field} was True in the baseline, now {got}"
        elif field == "metric_value":
            metric = base.get("metric", "")
            if metric in HIGHER_BETTER_METRICS and got < value - tol_metric:
                yield (
                    f"{name}: {metric} regressed {value:.4f} -> {got:.4f} "
                    f"(tolerance {tol_metric})"
                )
            if metric in LOWER_BETTER_METRICS and got > value + tol_metric:
                yield (
                    f"{name}: {metric} regressed {value:.4f} -> {got:.4f} "
                    f"(tolerance {tol_metric})"
                )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="freshly generated BENCH_compiler.json")
    ap.add_argument(
        "--baseline",
        default=str(DEFAULT_BASELINE),
        help="committed baseline JSON (default: benchmarks/baseline.json)",
    )
    ap.add_argument(
        "--tol",
        type=float,
        default=0.25,
        help="relative tolerance for cycles/energy regressions (default 0.25)",
    )
    ap.add_argument(
        "--tol-metric",
        type=float,
        default=0.05,
        help="absolute tolerance for accuracy/AEE regressions (default 0.05)",
    )
    ap.add_argument(
        "--tol-roofline",
        type=float,
        default=3.0,
        help="relative tolerance for the wall_us/bound_us roofline ratio "
        "(default 3.0; applies only to records whose baseline has both "
        "fields)",
    )
    ap.add_argument(
        "--subset",
        action="store_true",
        help="the fresh file covers only part of the baseline (e.g. a "
        "--qat-sweep run): gate the overlapping records instead of "
        "failing on the missing ones",
    )
    args = ap.parse_args(argv)

    base = _load(pathlib.Path(args.baseline))
    fresh = _load(pathlib.Path(args.fresh))
    if args.subset:
        base = {k: v for k, v in base.items() if k in fresh}
        if not base:
            raise SystemExit(
                "ERROR: --subset run shares no record names with the baseline"
            )

    failures: list = []
    improvements = 0
    for name, record in sorted(base.items()):
        if name not in fresh:
            failures.append(
                f"{name}: record missing from the fresh run (ablation "
                "removed or renamed?)"
            )
            continue
        errs = list(_check_record(record, fresh[name], args.tol,
                                  args.tol_metric, args.tol_roofline))
        failures.extend(errs)
        if not errs:
            for field, lower_better in COST_FIELDS.items():
                got, ref = fresh[name].get(field), record.get(field)
                if got is not None and ref is not None and got < ref:
                    improvements += 1
                    break
            print(f"PASS {name}")
    new = sorted(set(fresh) - set(base))
    if new:
        print(f"note: {len(new)} new record(s) not in the baseline: {new}")
    if improvements:
        print(
            f"note: {improvements} record(s) improved on the baseline — "
            "consider refreshing it to lock in the gains"
        )

    if failures:
        print(f"\nFAILED: {len(failures)} regression(s) vs {args.baseline}:")
        for f in failures:
            print(f"  - {f}")
        print()
        print(REFRESH_HINT)
        return 1
    print(f"OK: {len(base)} record(s) within tolerance of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
