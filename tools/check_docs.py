#!/usr/bin/env python3
"""Docs smoke check: extract and exec every ```python block in the docs.

Documentation rots when its examples silently stop running.  This tool
walks README.md and docs/*.md, pulls out every fenced ```python block, and
executes each one in a fresh namespace (snippet stdout suppressed unless it
fails).  CI runs it as the `docs` job; `tests/test_docs.py` runs the same
checks under pytest so a stale snippet fails locally too.

Rules for doc authors:
  * every ```python block must be self-contained and runnable on CPU in a
    few seconds (use reduced configs, the jnp backend, or interpret=True);
  * shell examples belong in ```bash blocks (not executed here);
  * illustrative pseudo-code belongs in plain ``` blocks.

Usage: PYTHONPATH=src python tools/check_docs.py [files...]
"""
from __future__ import annotations

import contextlib
import io
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]


def extract_python_blocks(text: str):
    """Yield (first_line_number, source) for each ```python fence."""
    lines = text.splitlines()
    block, start, in_block = [], 0, False
    for i, line in enumerate(lines, 1):
        if not in_block and line.strip() == "```python":
            in_block, block, start = True, [], i + 1
        elif in_block and line.strip() == "```":
            in_block = False
            yield start, "\n".join(block)
        elif in_block:
            block.append(line)


def run_file(path: pathlib.Path) -> list:
    """Exec every python block in ``path``; return a list of failures."""
    failures = []
    for lineno, src in extract_python_blocks(path.read_text()):
        name = f"{path.name}:{lineno}"
        buf = io.StringIO()
        try:
            code = compile(src, name, "exec")
            with contextlib.redirect_stdout(buf):
                exec(code, {"__name__": f"__doc_snippet_{lineno}__"})
            print(f"PASS {name}")
        except Exception as e:  # noqa: BLE001 - report and keep going
            print(f"FAIL {name}: {type(e).__name__}: {e}")
            out = buf.getvalue()
            if out:
                print(out, end="")
            failures.append((name, e))
    return failures


def main(argv=None) -> int:
    files = [pathlib.Path(a) for a in (argv or sys.argv[1:])] or DEFAULT_FILES
    failures = []
    for path in files:
        failures += run_file(path)
    n = len(failures)
    print(f"{'FAILED' if n else 'OK'}: {n} failing snippet(s) "
          f"across {len(files)} file(s)")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
